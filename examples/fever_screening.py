"""The paper's flagship application (Fig. 3): Free-Flow Fever Screening,
rebuilt 1:1 on the platform with ML-flavoured payloads.

Topology (exactly the paper's): 2 sensors (thermal + RGB cameras), 2 driver
instances, 5 analytics units (detect -> track -> align -> fuse -> screen),
1 platform database (track state), 1 actuator driving the entry-gate gadget.

Every box is pure business logic — the operator wires the streams, scales
instances, restarts crashes, and owns the database.

Run:  PYTHONPATH=src python examples/fever_screening.py
"""
import time

import numpy as np

from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        ConfigSchema, DatabaseSpec, DriverSpec, FieldSpec,
                        GadgetSpec, Operator, SensorSpec, StreamSchema,
                        StreamSpec)

FRAME = StreamSchema.of(frame_id=FieldSpec("int"), data=FieldSpec("ndarray"))
VERDICT = StreamSchema.of(frame_id=FieldSpec("int"), fever=FieldSpec("bool"),
                          temp_c=FieldSpec("float"))


def camera_driver(ctx):
    rng = np.random.default_rng(ctx.config["seed"])
    period = 1.0 / ctx.config["fps"]

    def gen():
        for i in range(ctx.config["frames"]):
            if not ctx.running:
                return
            time.sleep(period)
            yield {"frame_id": i,
                   "data": rng.random((16, 16)).astype(np.float32)
                   * ctx.config["gain"]}
    return gen()


def face_detector(ctx):
    return lambda s, p: {"frame_id": p["frame_id"],
                         "data": p["data"][4:12, 4:12]}  # "face crop"


def tracker(ctx):
    table = ctx.db.ensure_table("tracks", ["first_seen"]) if ctx.db else None

    def process(s, p):
        if table is not None and table.get(p["frame_id"] % 7) is None:
            table.put(p["frame_id"] % 7, {"first_seen": p["frame_id"]})
        return p
    return process


def alignment(ctx):
    return lambda s, p: {"frame_id": p["frame_id"],
                         "data": p["data"][4:12, 4:12]}


_pending: dict = {}


def fusion(ctx):
    def process(stream, p):
        other = _pending.pop(p["frame_id"], None)
        if other is None:
            _pending[p["frame_id"]] = p
            return None
        return {"frame_id": p["frame_id"],
                "data": (p["data"] + other["data"]) / 2}
    return process


def screening(ctx):
    thr = ctx.config["fever_c"]

    def process(s, p):
        temp = 36.0 + float(p["data"].mean()) * 3.0
        return {"frame_id": p["frame_id"], "fever": bool(temp > thr),
                "temp_c": temp}
    return process


def gate_actuator(ctx):
    def process(s, p):
        action = "HOLD + alert" if p["fever"] else "open"
        print(f"frame {p['frame_id']:3d}: {p['temp_c']:.1f}C -> gate {action}")
    return process


def main() -> None:
    app = Application(name="fever-screening")
    app.driver(DriverSpec(
        name="camera", logic=camera_driver,
        config_schema=ConfigSchema.of(seed=("int", 0), frames=("int", 40),
                                      fps=("float", 40.0),
                                      gain=("float", 1.0)),
        output_schema=FRAME))
    for name, logic in [("detector", face_detector), ("tracker", tracker),
                        ("alignment", alignment), ("fusion", fusion)]:
        app.analytics_unit(AnalyticsUnitSpec(
            name=name, logic=logic, output_schema=FRAME,
            stateful=(name == "tracker")))
    app.analytics_unit(AnalyticsUnitSpec(
        name="screening", logic=screening,
        config_schema=ConfigSchema.of(fever_c=("float", 37.6)),
        output_schema=VERDICT))
    app.actuator(ActuatorSpec(name="gate", logic=gate_actuator))
    app.database(DatabaseSpec(name="track-db",
                              tables={"tracks": ["first_seen"]}))
    app.sensor(SensorSpec(name="thermal", driver="camera",
                          config={"seed": 1, "gain": 1.1}))
    app.sensor(SensorSpec(name="rgb", driver="camera",
                          config={"seed": 2}))
    app.stream(StreamSpec(name="detections", analytics_unit="detector",
                          inputs=("rgb",)))
    app.stream(StreamSpec(name="tracks", analytics_unit="tracker",
                          inputs=("detections",), fixed_instances=1))
    app.stream(StreamSpec(name="aligned-thermal", analytics_unit="alignment",
                          inputs=("thermal",)))
    app.stream(StreamSpec(name="fused", analytics_unit="fusion",
                          inputs=("tracks", "aligned-thermal"),
                          fixed_instances=1))
    app.stream(StreamSpec(name="screenings", analytics_unit="screening",
                          inputs=("fused",)))
    app.gadget(GadgetSpec(name="entry-gate", actuator="gate",
                          inputs=("screenings",)))

    op = Operator()
    app.deploy(op)
    op.start()
    print(f"deployed: {app.loc_footprint()} entities; streams:",
          op.registered_streams())
    time.sleep(3.0)
    print("\nsidecar metrics (the numbers that drive autoscaling):")
    for iid, m in sorted(op.metrics().items()):
        print(f"  {iid:38s} recv={m['received']:3d} pub={m['published']:3d} "
              f"lat={m['latency_ewma_s']*1e6:5.0f}us")
    print("\ntrack DB rows:", len(op.store.get("au-tracks").table("tracks")))
    op.shutdown()


if __name__ == "__main__":
    main()
