"""The paper's flagship application (Fig. 3): Free-Flow Fever Screening,
rebuilt on the v2 fluent API with ML-flavoured payloads.

Topology (exactly the paper's): 2 sensors (thermal + RGB cameras), 2 driver
instances, 5 analytics units (detect -> track -> align -> fuse -> screen),
1 platform database (track state), 1 actuator driving the entry-gate gadget.

Every box is pure business logic — the operator wires the streams, scales
instances, restarts crashes, and owns the database.  Compare with the v1
spec-style build of this same topology in tests/test_system.py.

Run:  PYTHONPATH=src python examples/fever_screening.py
"""
import time

import numpy as np

from repro.core import (App, FieldSpec, StreamHandle, StreamSchema, connect)

FRAME = StreamSchema.of(frame_id=FieldSpec("int"), data=FieldSpec("ndarray"))
VERDICT = StreamSchema.of(frame_id=FieldSpec("int"), fever=FieldSpec("bool"),
                          temp_c=FieldSpec("float"))

app = App("fever-screening")


@app.driver(emits=FRAME)
def camera(ctx, seed=0, frames=40, fps=40.0, gain=1.0):
    rng = np.random.default_rng(seed)
    period = 1.0 / fps

    def gen():
        for i in range(frames):
            if not ctx.running:
                return
            time.sleep(period)
            yield {"frame_id": i,
                   "data": rng.random((16, 16)).astype(np.float32) * gain}
    return gen()


@app.analytics_unit(expects=(FRAME,), emits=FRAME)
def detector(ctx):
    return lambda s, p: {"frame_id": p["frame_id"],
                         "data": p["data"][4:12, 4:12]}  # "face crop"


@app.analytics_unit(expects=(FRAME,), emits=FRAME, stateful=True)
def tracker(ctx):
    table = ctx.db.ensure_table("tracks", ["first_seen"]) if ctx.db else None

    def process(s, p):
        if table is not None and table.get(p["frame_id"] % 7) is None:
            table.put(p["frame_id"] % 7, {"first_seen": p["frame_id"]})
        return p
    return process


@app.analytics_unit(expects=(FRAME,), emits=FRAME)
def alignment(ctx):
    return lambda s, p: {"frame_id": p["frame_id"],
                         "data": p["data"][4:12, 4:12]}


_pending: dict = {}


@app.analytics_unit(expects=(FRAME, FRAME), emits=FRAME)
def fusion(ctx):
    def process(stream, p):
        other = _pending.pop(p["frame_id"], None)
        if other is None:
            _pending[p["frame_id"]] = p
            return None
        return {"frame_id": p["frame_id"],
                "data": (p["data"] + other["data"]) / 2}
    return process


@app.analytics_unit(expects=(FRAME,), emits=VERDICT)
def screening(ctx, fever_c=37.6):
    def process(s, p):
        temp = 36.0 + float(p["data"].mean()) * 3.0
        return {"frame_id": p["frame_id"], "fever": bool(temp > fever_c),
                "temp_c": temp}
    return process


@app.actuator(expects=(VERDICT,))
def gate(ctx):
    def process(s, p):
        action = "HOLD + alert" if p["fever"] else "open"
        print(f"frame {p['frame_id']:3d}: {p['temp_c']:.1f}C -> gate {action}")
    return process


def build_app() -> App:
    """Wire the paper's Fig. 3 topology and return the app — also the
    entry point ``datax check`` discovers."""
    app.database("track-db", tables={"tracks": ["first_seen"]})
    thermal = app.sense("thermal", camera, seed=1, gain=1.1)
    rgb = app.sense("rgb", camera, seed=2)
    tracks = (rgb.via(detector, name="detections")
                 .via(tracker, name="tracks", fixed_instances=1))
    aligned = thermal.via(alignment, name="aligned-thermal")
    fused = StreamHandle.fuse(tracks, aligned, with_=fusion, name="fused",
                              fixed_instances=1)
    verdicts = fused.via(screening, name="screenings")
    verdicts >> app.gadget("entry-gate", gate)
    return app


def main() -> None:
    build_app()
    with connect() as op:
        app.deploy(op)
        print(f"deployed: {app.loc_footprint()} entities; streams:",
              op.registered_streams())
        time.sleep(3.0)
        print("\nsidecar metrics (the numbers that drive autoscaling):")
        for iid, m in sorted(op.metrics().items()):
            print(f"  {iid:38s} recv={m['received']:3d} pub={m['published']:3d} "
                  f"lat={m['latency_ewma_s']*1e6:5.0f}us")
        print("\ntrack DB rows:", len(op.store.get("au-tracks").table("tracks")))


if __name__ == "__main__":
    main()
