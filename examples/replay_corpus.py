"""Durable streams (PR 6): a late-joining analytics app replays history.

App 1: a ticketing feed publishes events onto a DURABLE subject — every
message is retained in an append-only log (``.durable(retention=...)``),
so the stream's history outlives whoever was subscribed at publish time.

App 2 (deployed AFTER the feed has been running): a revenue dashboard that
``replay_from="earliest"`` — it first drains the full retained history from
the log, then flips to live delivery with no gap and no duplicate.  The
producer app is never modified and never re-run; the history was already
on the bus.

Run:  PYTHONPATH=src python examples/replay_corpus.py
"""
import time

from repro.core import App, FieldSpec, StreamSchema, connect

SALE = StreamSchema.of(region=FieldSpec("str"), amount=FieldSpec("int"))


def feed_app() -> App:
    app = App("ticket-feed")

    @app.driver(emits=SALE)
    def sales(ctx, n=60):
        def gen():
            for i in range(n):
                if not ctx.running:
                    return
                time.sleep(0.005)
                yield {"region": f"r{i % 3}", "amount": 10 + i % 7}
        return gen()

    # .durable(): attach an append-only log to the subject; late consumers
    # can replay it.  Retention bounds how much history is kept.
    app.sense("sales", sales).durable(retention={"max_records": 10_000})
    return app


def dashboard_app() -> App:
    """Deployed later: folds per-region revenue over history + live."""
    app = App("revenue-dashboard")

    totals = (app.external("sales", SALE)
              .key_by("region")
              .reduce(lambda acc, p: (acc or 0) + p["amount"],
                      name="revenue"))
    # .replay(): when the stage spawns, it reads the durable input from the
    # start before going live — exactly-once per message via apply_once.
    # The output is durable too, so OUR late subscribers can replay it.
    totals.replay(from_="earliest").durable()
    return app


def main() -> None:
    with connect() as op:
        feed_app().deploy(op)
        # let the feed run for a while with NOBODY listening — on a
        # fire-and-forget subject this history would simply be gone
        time.sleep(1.0)
        depth = op.bus.stats()["sales"]["durable"]["depth"]
        print(f"feed has published {depth} events; no consumer was attached")

        dashboard_app().deploy(op)
        sub = op.subscribe("revenue", name="dashboard",
                           replay_from="earliest")
        seen, finals = 0, {}
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline:
            m = sub.next(timeout=0.5)
            if m is None:
                if seen >= 60:
                    break
                continue
            seen += 1
            finals[m.payload["region"]] = m.payload["value"]
        print(f"dashboard folded {seen} events (history replayed + live): "
              f"{dict(sorted(finals.items()))}")
        assert seen >= depth, "replay must cover the pre-join history"
        print("late joiner saw every event: reuse cost = 1 .replay()")


if __name__ == "__main__":
    main()
