"""Quickstart: a complete DataX application on the v2 fluent API.

A temperature sensor streams readings; an AU computes a rolling anomaly
score; an actuator raises an alarm gadget.  No communication code anywhere —
the platform wires the streams (the paper's core productivity claim).

Entities are declared with decorators (config schemas inferred from keyword
defaults, stream schemas from ``emits=``); the topology is two lines of
stream combinators.  The v1 spec-style equivalent of this file needed ~17
lines of ``*Spec`` plumbing — see ``examples/stream_reuse.py`` for the
spec-style surface, or README.md for the side-by-side.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import random
import time

from repro.core import App, FieldSpec, StreamSchema, connect

READING = StreamSchema.of(t=FieldSpec("float"))
SCORE = StreamSchema.of(t=FieldSpec("float"), score=FieldSpec("float"))

app = App("quickstart")


@app.driver(emits=READING)
def thermometer(ctx, n=200):                # driver: the business logic only
    def gen():
        for i in range(n):
            base = 21.0 + random.gauss(0, 0.3)
            if i % 37 == 13:                # inject anomalies
                base += 9.0
            yield {"t": base}
    return gen()


@app.analytics_unit(expects=(READING,), emits=SCORE)
def anomaly(ctx):                           # AU: rolling z-score
    window: list[float] = []

    def process(stream, msg):
        window.append(msg["t"])
        if len(window) > 32:
            window.pop(0)
        mean = sum(window) / len(window)
        var = sum((x - mean) ** 2 for x in window) / max(len(window) - 1, 1)
        score = abs(msg["t"] - mean) / (var ** 0.5 + 1e-6)
        return {"t": msg["t"], "score": score}
    return process


@app.actuator(expects=(SCORE,))
def alarm(ctx, threshold=4.0):              # actuator: controls the gadget
    def process(stream, msg):
        if msg["score"] > threshold:
            print(f"ALARM  t={msg['t']:.1f}C  score={msg['score']:.1f}")
    return process


def build_app() -> App:
    """Wire the topology (sensor -> anomaly AU -> siren gadget) and return
    the app — also the entry point ``datax check`` discovers."""
    scores = app.sense("lab-temp", thermometer, n=200).via(anomaly,
                                                           name="anomalies")
    scores >> app.gadget("siren", alarm)
    return app


def main() -> None:
    build_app()
    with connect() as op:
        app.deploy(op)
        time.sleep(3)
        print("\nplatform view:", op.describe())
        print("metrics:", {k: v["processed"] for k, v in op.metrics().items()})


if __name__ == "__main__":
    main()
