"""Quickstart: a complete DataX application in ~30 lines of business logic.

A temperature sensor streams readings; an AU computes a rolling anomaly
score; an actuator raises an alarm gadget.  No communication code anywhere —
the platform wires the streams (the paper's core productivity claim).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import random
import time

from repro.core import (ActuatorSpec, AnalyticsUnitSpec, ConfigSchema,
                        DriverSpec, FieldSpec, GadgetSpec, Operator,
                        SensorSpec, StreamSchema, StreamSpec)

READING = StreamSchema.of(t=FieldSpec("float"))
SCORE = StreamSchema.of(t=FieldSpec("float"), score=FieldSpec("float"))


def thermometer(ctx):                       # driver: the business logic only
    def gen():
        for i in range(ctx.config["n"]):
            base = 21.0 + random.gauss(0, 0.3)
            if i % 37 == 13:                # inject anomalies
                base += 9.0
            yield {"t": base}
    return gen()


def anomaly_scorer(ctx):                    # AU: rolling z-score
    window: list[float] = []

    def process(stream, msg):
        window.append(msg["t"])
        if len(window) > 32:
            window.pop(0)
        mean = sum(window) / len(window)
        var = sum((x - mean) ** 2 for x in window) / max(len(window) - 1, 1)
        score = abs(msg["t"] - mean) / (var ** 0.5 + 1e-6)
        return {"t": msg["t"], "score": score}
    return process


def alarm(ctx):                             # actuator: controls the gadget
    def process(stream, msg):
        if msg["score"] > ctx.config["threshold"]:
            print(f"ALARM  t={msg['t']:.1f}C  score={msg['score']:.1f}")
    return process


def main() -> None:
    op = Operator()
    op.register_driver(DriverSpec(
        name="thermometer", logic=thermometer,
        config_schema=ConfigSchema.of(n=("int", 200)), output_schema=READING))
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="anomaly", logic=anomaly_scorer, output_schema=SCORE))
    op.register_actuator(ActuatorSpec(
        name="alarm", logic=alarm,
        config_schema=ConfigSchema.of(threshold=("float", 4.0))))

    op.register_sensor(SensorSpec(name="lab-temp", driver="thermometer"),
                       start=False)
    op.create_stream(StreamSpec(name="anomalies", analytics_unit="anomaly",
                                inputs=("lab-temp",)))
    op.register_gadget(GadgetSpec(name="siren", actuator="alarm",
                                  inputs=("anomalies",)))
    op.start()
    op.start_pending_sensors()
    time.sleep(3)
    print("\nplatform view:", op.describe())
    print("metrics:", {k: v["processed"] for k, v in op.metrics().items()})
    op.shutdown()


if __name__ == "__main__":
    main()
