"""Claim §3 "Effortless data streams reuse": a second application subscribes
to a stream registered by the first — no producer changes, no new plumbing.

App 1: security camera -> object detections.
App 2 (deployed later, by a different team): subscribes to `detections`
and builds a people-counter dashboard, reusing both the stream AND the
registered AU catalog.

Run:  PYTHONPATH=src python examples/stream_reuse.py
"""
import time

import numpy as np

from repro.core import (AnalyticsUnitSpec, ConfigSchema, DriverSpec,
                        FieldSpec, Operator, SensorSpec, StreamSchema,
                        StreamSpec)

FRAME = StreamSchema.of(frame_id=FieldSpec("int"), n_people=FieldSpec("int"))


def main() -> None:
    op = Operator()

    # ----- app 1: camera -> detector ---------------------------------------
    def camera(ctx):
        rng = np.random.default_rng(0)

        def gen():
            for i in range(ctx.config["frames"]):
                if not ctx.running:
                    return
                time.sleep(0.01)
                yield {"frame_id": i, "n_people": int(rng.integers(0, 5))}
        return gen()

    def detector(ctx):
        return lambda s, p: {"frame_id": p["frame_id"],
                             "n_people": p["n_people"]}

    op.register_driver(DriverSpec(
        name="camera", logic=camera,
        config_schema=ConfigSchema.of(frames=("int", 150)),
        output_schema=FRAME))
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="detector", logic=detector, output_schema=FRAME))
    op.register_sensor(SensorSpec(name="lobby-cam", driver="camera"),
                       start=False)
    op.create_stream(StreamSpec(name="detections", analytics_unit="detector",
                                inputs=("lobby-cam",)))
    op.start()

    # ----- app 2: a different team reuses 'detections' ----------------------
    print("app2 discovers registered streams:", op.registered_streams())

    def counter(ctx):
        total = {"n": 0}

        def process(s, p):
            total["n"] += p["n_people"]
            return {"frame_id": p["frame_id"], "n_people": total["n"]}
        return process

    op.register_analytics_unit(AnalyticsUnitSpec(
        name="people-counter", logic=counter, output_schema=FRAME))
    op.create_stream(StreamSpec(name="occupancy", analytics_unit="people-counter",
                                inputs=("detections",), fixed_instances=1))
    dashboard = op.subscribe("occupancy", name="dashboard")
    op.start_pending_sensors()

    seen = 0
    last = None
    deadline = time.monotonic() + 20
    while seen < 100 and time.monotonic() < deadline:
        m = dashboard.next(timeout=0.5)
        if m:
            seen += 1
            last = m.payload
    print(f"dashboard consumed {seen} occupancy updates; "
          f"cumulative count = {last['n_people'] if last else '?'}")
    print("producer app was never modified: reuse cost = 1 StreamSpec")
    op.shutdown()


if __name__ == "__main__":
    main()
