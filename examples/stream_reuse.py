"""Claim §3 "Effortless data streams reuse": a second application subscribes
to a stream registered by the first — no producer changes, no new plumbing.

App 1: security camera -> object detections (v2 fluent DSL).
App 2 (deployed later, by a different team): picks up `detections` with
``app.external(...)`` and builds a people-counter dashboard, reusing both the
stream AND the live operator — the producer app is never modified.

(The spec-style v1 surface is still covered by examples/serve_lm.py and
examples/train_lm.py.)

Run:  PYTHONPATH=src python examples/stream_reuse.py
"""
import time

import numpy as np

from repro.core import App, FieldSpec, StreamSchema, connect

FRAME = StreamSchema.of(frame_id=FieldSpec("int"), n_people=FieldSpec("int"))


def camera_app() -> App:
    app = App("camera-app")

    @app.driver(emits=FRAME)
    def camera(ctx, frames=150):
        rng = np.random.default_rng(0)

        def gen():
            for i in range(frames):
                if not ctx.running:
                    return
                time.sleep(0.01)
                yield {"frame_id": i, "n_people": int(rng.integers(0, 5))}
        return gen()

    @app.analytics_unit(expects=(FRAME,), emits=FRAME)
    def detector(ctx):
        return lambda s, p: {"frame_id": p["frame_id"],
                             "n_people": p["n_people"]}

    # .tap(): promise `detections` to external subscribers — it always stays
    # a bus subject, even if this chain later gains device stages that fuse
    app.sense("lobby-cam", camera).via(detector, name="detections").tap()
    return app


def dashboard_app() -> App:
    """A different team's app: consumes `detections` without owning it."""
    app = App("dashboard-app")

    @app.analytics_unit(expects=(FRAME,), emits=FRAME)
    def people_counter(ctx):
        total = {"n": 0}

        def process(s, p):
            total["n"] += p["n_people"]
            return {"frame_id": p["frame_id"], "n_people": total["n"]}
        return process

    # .tap() promises `occupancy` to external subscribers (the dashboard's
    # op.subscribe below) — without it, datax check flags a dead stream
    app.external("detections", FRAME).via(people_counter, name="occupancy",
                                          fixed_instances=1).tap()
    return app


def main() -> None:
    with connect() as op:
        camera_app().deploy(op, start_sensors=False)

        # ----- app 2: a different team reuses 'detections' ------------------
        print("app2 discovers registered streams:", op.registered_streams())
        dashboard_app().deploy(op, start_sensors=False)
        dashboard = op.subscribe("occupancy", name="dashboard")
        op.start_pending_sensors()

        seen = 0
        last = None
        deadline = time.monotonic() + 20
        while seen < 100 and time.monotonic() < deadline:
            m = dashboard.next(timeout=0.5)
            if m:
                seen += 1
                last = m.payload
        print(f"dashboard consumed {seen} occupancy updates; "
              f"cumulative count = {last['n_people'] if last else '?'}")
        print("producer app was never modified: reuse cost = 1 external() + "
              "1 .via()")


if __name__ == "__main__":
    main()
