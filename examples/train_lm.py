"""End-to-end training driver: train an LM through the DataX pipeline.

The data pipeline is a **v2 fluent-DSL app** (the last spec-style holdout
migrated): corpus sensor -> packer AU -> batcher AU, wired with decorators
and ``.via`` combinators; the Trainer attaches to the resulting ``batches``
stream as just another subscriber (§3 stream reuse) and drives the pjit
train-step device AU -> {async checkpoints, metrics}.  Fault tolerance is
live: Ctrl-C (or --preempt-at) triggers the preemption path (blocking
checkpoint, clean exit); re-running the same command resumes.

CPU-sized default (a few M params).  On a real slice, pass --preset 100m
(or use repro.launch.train with --arch) and scale steps/batch.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 100
"""
import argparse
import dataclasses

from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig, RunConfig
from repro.core import App, Operator
from repro.data import corpus as corpus_mod
from repro.data import pipeline as pipe
from repro.train.trainer import Trainer, TrainerConfig


def preset_config(name: str) -> ModelConfig:
    if name == "tiny":          # ~4M params: runs on this CPU container
        return dataclasses.replace(
            get_smoke_config("qwen3-14b"), n_layers=4, d_model=128,
            n_heads=4, n_kv_heads=2, d_ff=512, vocab=4096, head_dim=32)
    if name == "100m":          # ~100M params: for real hardware
        return dataclasses.replace(
            get_smoke_config("qwen3-14b"), n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32768, head_dim=64)
    raise SystemExit(f"unknown preset {name}")


def pipeline_app(cfg: ModelConfig, tcfg: TrainerConfig) -> App:
    """corpus -> packer -> batcher, declared fluently.

    The business logic is the shared library AUs (repro.data) — the app
    only *wires* them, which is the v1-vs-v2 productivity delta."""
    app = App("train-pipeline")
    app.driver(corpus_mod.corpus_driver, name="corpus",
               emits=corpus_mod.CORPUS_SCHEMA, config=corpus_mod.CORPUS_CONFIG)
    app.analytics_unit(pipe.packer_au, name="packer",
                       emits=pipe.PACKED_SCHEMA, config=pipe.PACKER_CONFIG,
                       max_instances=4)
    app.analytics_unit(pipe.batcher_au, name="batcher",
                       emits=pipe.BATCH_SCHEMA, config=pipe.BATCHER_CONFIG,
                       max_instances=1)
    docs = app.sense("docs", "corpus", vocab=cfg.vocab, seed=tcfg.seed)
    sequences = docs.via("packer", name="sequences", seq_len=tcfg.seq_len)
    # the batcher accumulates across messages -> single instance; .tap()
    # promises `batches` to its external subscriber (the Trainer)
    sequences.via("batcher", name="batches", batch=tcfg.global_batch,
                  fixed_instances=1).tap()
    return app


def build_app() -> App:
    """CPU-sized pipeline app with default knobs — the entry point
    ``datax check`` discovers (main() parameterizes via pipeline_app)."""
    return pipeline_app(preset_config("tiny"), TrainerConfig())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workdir", default="/tmp/repro-train-example")
    ap.add_argument("--preempt-at", type=int, default=0,
                    help="simulate preemption after N steps")
    args = ap.parse_args()

    cfg = preset_config(args.preset)
    run = RunConfig(attention_impl="chunked", attention_chunk=128,
                    remat="none", learning_rate=3e-3, warmup_steps=20)
    tcfg = TrainerConfig(global_batch=args.batch, seq_len=args.seq,
                         ckpt_every=25, total_steps=args.steps,
                         workdir=args.workdir)

    op = Operator(reconcile_interval_s=0.2)
    pipeline_app(cfg, tcfg).deploy(op, start_sensors=False)
    op.start()
    tr = Trainer(cfg, run, tcfg, operator=op, deploy_pipeline=False)
    tr.init_or_restore()
    op.start_pending_sensors()   # no data flows before the trainer subscribed
    if tr.step:
        print(f"resumed from checkpoint at step {tr.step}")
    print(f"training {cfg.param_count()/1e6:.1f}M params "
          f"({args.preset}); target {args.steps} steps")
    try:
        while tr.step < args.steps:
            if args.preempt_at and tr.step >= args.preempt_at:
                print("simulating preemption notice...")
                tr.preemption.preempt()
            got = tr.run_steps(min(10, args.steps - tr.step))
            if not got:
                break
            m = got[-1]
            print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
                  f"lr {m['lr']:.2e}  {m['step_time_s']*1e3:.0f} ms/step"
                  + ("  [straggler]" if m["straggler"] else ""))
    except KeyboardInterrupt:
        print("interrupted: writing preemption checkpoint")
        tr.preemption.preempt()
        tr.run_steps(1)
    finally:
        tr.close()
        op.shutdown()
    print(f"done at step {tr.step}; checkpoints in {args.workdir}/ckpt")


if __name__ == "__main__":
    main()
