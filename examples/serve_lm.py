"""Serve a small LM with continuously-batched requests — as a v2 DSL app.

The serving loop is a real DataX application (migrated from the raw-Operator
v1 style): a request driver feeds a ``requests`` stream, an SDK-style engine
analytics unit owns the continuous-batching loop (submit -> tick -> emit),
and responses land on a ``responses`` stream any consumer can reuse (§3).

The request stream is **keyed by session** (``.key_by("session")``): every
session's requests reach the same engine instance in order, and the KV slot
table lives in the stream's platform database — exactly the per-session
state locality that lets ``.scaled(instances=N)`` shard sessions across N
engines without forking their state (this example keeps one engine so the
jit compile is paid once).

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.core import (App, ConfigSchema, FieldSpec, StreamSchema, connect,
                        drain, sdk_entrypoint)

REQUEST = StreamSchema.of(
    request_id=FieldSpec("str"), session=FieldSpec("str"),
    prompt=FieldSpec("ndarray", shape=(-1,), dtype="int32"),
    max_new=FieldSpec("int"))
RESPONSE = StreamSchema.of(
    request_id=FieldSpec("str"), session=FieldSpec("str"),
    prompt_len=FieldSpec("int"), tokens=FieldSpec("int"),
    ttft_ms=FieldSpec("float"))

app = App("serve-lm")


@app.driver(emits=REQUEST)
def request_gen(ctx, requests=12, sessions=3, vocab=4096, seed=0):
    rng = np.random.default_rng(seed)

    def gen():
        for i in range(requests):
            if not ctx.running:
                return
            prompt = rng.integers(1, vocab, int(rng.integers(4, 24)),
                                  dtype=np.int32)
            yield {"request_id": f"req-{i:03d}",
                   "session": f"sess-{i % sessions}",
                   "prompt": prompt,
                   "max_new": 16}
    return gen()


@app.analytics_unit(expects=(REQUEST,), emits=RESPONSE, stateful=True,
                    config=ConfigSchema.of(slots=("int", 4),
                                           max_new=("int", 16)))
@sdk_entrypoint
def lm_engine(dx):
    """SDK-style engine: owns its loop, three-method SDK + platform db."""
    import jax

    from repro import models
    from repro.serve import ServeEngine

    cfg = dataclasses.replace(
        get_smoke_config("qwen3-14b"), n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=4096, head_dim=32)
    run = RunConfig(attention_impl="naive", remat="none")
    params = models.init(jax.random.PRNGKey(0), cfg)
    conf = dx.get_configuration()
    # the KV slot table lives in the stream's platform database: an engine
    # restart — or a session re-homed by keyed rebalance — recovers its map
    engine = ServeEngine(cfg, run, params, n_slots=conf["slots"],
                         max_seq=256, db=dx.db)
    sessions: dict[str, str] = {}
    while dx.running:
        item = dx.next(timeout=0.02)
        if item is not None:
            _, payload = item
            sessions[payload["request_id"]] = payload["session"]
            engine.submit(payload["request_id"],
                          [int(t) for t in payload["prompt"]],
                          max_new_tokens=min(payload["max_new"],
                                             conf["max_new"]))
        if not engine.batcher.idle:
            for req in engine.tick():
                dx.emit({"request_id": req.request_id,
                         "session": sessions.pop(req.request_id, ""),
                         "prompt_len": len(req.prompt),
                         "tokens": len(req.generated),
                         "ttft_ms": (req.first_token_at - req.arrived) * 1e3})


def build_app(requests=12, slots=4, max_new=16) -> App:
    """Wire the serving topology (request driver -> session-keyed engine ->
    tapped responses) and return the app — also the entry point
    ``datax check`` discovers."""
    reqs = app.sense("requests", request_gen, requests=requests)
    responses = (reqs.key_by("session")
                 .via(lm_engine, name="responses", slots=slots,
                      max_new=max_new, fixed_instances=1))
    responses.tap()   # promised to external consumers (§3 reuse)
    return app


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    build_app(requests=args.requests, slots=args.slots,
              max_new=args.max_new)

    t0 = time.perf_counter()
    with connect() as op:
        app.deploy(op, start_sensors=False)
        sub = op.subscribe("responses", maxsize=args.requests + 8)
        op.start_pending_sensors()
        done = drain(sub, args.requests, timeout=600)
        dt = time.perf_counter() - t0
        toks = sum(m.payload["tokens"] for m in done)
        print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
              f"({toks/dt:.0f} tok/s) with {args.slots} KV slots")
        for m in sorted(done, key=lambda m: m.payload["request_id"])[:5]:
            p = m.payload
            print(f"  {p['request_id']} ({p['session']}): "
                  f"{p['prompt_len']}-token prompt -> {p['tokens']} tokens, "
                  f"ttft {p['ttft_ms']:.0f} ms")
        group = (op.executor.instances_of("responses")[0]
                 .sidecar.metrics()["groups"]["requests"])
        db = op.store.get("au-responses")
        print(f"request delivery: {group['policy']} on {group.get('key')!r} "
              f"({group['delivered']} delivered); KV slot table "
              f"{db.tables()} lives in platform db {db.name!r}")


if __name__ == "__main__":
    main()
