"""Serve a small LM with continuously-batched requests.

Requests arrive on a DataX stream (request sensor), the engine admits them
into KV slots as they free up, and responses land on a response stream.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import models
from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.core import Operator
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_smoke_config("qwen3-14b"), n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=4096, head_dim=32)
    run = RunConfig(attention_impl="naive", remat="none")
    params = models.init(jax.random.PRNGKey(0), cfg)

    # the KV slot table lives in a platform database: engine restarts
    # recover their session map (the paper's state management claim)
    op = Operator()
    db = op.store.create("serving-session")
    engine = ServeEngine(cfg, run, params, n_slots=args.slots, max_seq=256,
                         db=db)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = list(rng.integers(1, cfg.vocab, int(rng.integers(4, 24))))
        engine.submit(f"req-{i:03d}", prompt, max_new_tokens=args.max_new)
    done = engine.run_until_idle()
    dt = time.perf_counter() - t0

    toks = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.0f} tok/s) with {args.slots} KV slots")
    for r in sorted(done, key=lambda r: r.request_id)[:5]:
        ttft = (r.first_token_at - r.arrived) * 1e3
        print(f"  {r.request_id}: {len(r.prompt)}-token prompt -> "
              f"{len(r.generated)} tokens, ttft {ttft:.0f} ms")
    print("engine metrics:", engine.metrics)
    op.shutdown()


if __name__ == "__main__":
    main()
