#!/usr/bin/env bash
# CI entrypoint: deps + tier-1 tests + `datax check` over the shipped
# examples + headless runs of the examples + benchmark artifacts with the
# per-claim regression gates (fusion, grouped and keyed scaling,
# cross-process transport, durable overhead) + the docs
# link/fence check.  Runs on two matrix
# legs (.github/workflows/ci.yml): full deps, and minimal deps via
# CI_SKIP_INSTALL=1 (no jax/zstandard/hypothesis) to exercise every
# graceful-degradation path.
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Best-effort dependency install; the repo degrades gracefully without the
# optional ones (jax -> host-composed fusion, zstandard -> zlib fallback,
# hypothesis -> skipped tests).
if [ "${CI_SKIP_INSTALL:-0}" != "1" ]; then
    python -m pip install --quiet -r requirements.txt \
        || echo "ci.sh: pip install failed (offline?); using preinstalled deps"
fi

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== datax check (static dataflow analysis) =="
# every shipped example must be free of error-severity diagnostics (the CLI
# exits 1 on any surviving error; vetted exceptions use
# `# datax: ignore[DXnnn] reason` pragmas) — both matrix legs
python tools/datax_check.py examples/quickstart.py
python tools/datax_check.py examples/fever_screening.py
python tools/datax_check.py examples/stream_reuse.py
python tools/datax_check.py examples/replay_corpus.py

echo "== examples (headless) =="
python examples/quickstart.py
python examples/fever_screening.py
python examples/stream_reuse.py
python examples/replay_corpus.py
# the LM examples (now v2 fluent-DSL apps) need jax — full-deps leg only
if python -c "import jax" 2>/dev/null; then
    echo "== examples (headless, jax) =="
    python tools/datax_check.py examples/serve_lm.py
    python tools/datax_check.py examples/train_lm.py
    python examples/serve_lm.py --requests 6 --slots 3
    python examples/train_lm.py --steps 4 --batch 4 --seq 64 \
        --workdir "$(mktemp -d)"
fi

echo "== benchmarks: fusion regression gate =="
# writes BENCH_fusion.json; fails if the fused device chain is not faster
# than per-hop bus execution on the 4-stage benchmark topology, or (jax leg)
# if batched fused execution is not faster than per-message jitted dispatch
# (batched_msgs_per_s >= fused_jit_msgs_per_s)
python -m benchmarks.run --only fusion --gate

echo "== benchmarks: mesh-sharded fusion gate =="
# writes BENCH_mesh.json; a subprocess with
# XLA_FLAGS=--xla_force_host_platform_device_count=4 simulates a 4-device
# mesh — sharded fused bursts must not be slower than single-device batched
# and must be bit-identical to the host-composed chain (no jax -> the
# benchmark records "skipped" and the gate passes vacuously)
python -m benchmarks.run --only mesh --gate

echo "== benchmarks: queue-group scaling gate =="
# writes BENCH_scaling.json; fails unless 4 grouped workers beat 1 by >=2x
# on the 4-stage pipeline (pure platform code — runs on both matrix legs)
python -m benchmarks.run --only scaling --gate

echo "== benchmarks: keyed stateful scaling gate =="
# writes BENCH_keyed.json; fails unless 4 keyed STATEFUL workers beat 1 by
# >=2x with zero per-key ordering violations and zero lost state across a
# forced mid-run scale-down (pure platform code — runs on both matrix legs)
python -m benchmarks.run --only keyed --gate

echo "== benchmarks: cross-process transport gate =="
# writes BENCH_transport.json; a 2-process pipeline (driver here, grouped +
# keyed consumers in worker processes over TCP) must deliver every message
# exactly once — zero loss, zero double-delivery, zero per-key ordering
# violations — across a forced consumer-process kill (pure platform code —
# runs on both matrix legs)
python -m benchmarks.run --only transport --gate

echo "== benchmarks: wire fast-path gate =="
# writes BENCH_wire.json; coalesced frames must drain >=2x faster than
# per-message framing, with 0 lost / 0 duplicated / 0 reordered across a
# mid-run consumer kill under coalesced acks.  Codec check is per-leg: the
# full-deps leg must negotiate zstd with wire_ratio > 1, the minimal leg
# (no zstandard) must record a clean negotiate-down to zlib
python -m benchmarks.run --only wire --gate

echo "== benchmarks: durable publish overhead gate =="
# writes BENCH_durable.json; fails if publishing on a durable subject costs
# more than 2x fire-and-forget, or a late joiner's replay does not drain the
# full retained history (pure platform code — runs on both matrix legs)
python -m benchmarks.run --only durable --gate

echo "== benchmarks: productivity claim =="
# writes BENCH_loc.json
python -m benchmarks.run --only loc

echo "== docs check =="
# docs/ + README relative links must resolve; python fences in docs/*.md
# must compile (stdlib only — both matrix legs, also a standalone CI job)
python tools/check_docs.py

echo "== api surface check =="
# repro.core's public names + signatures must match the committed snapshot
# (docs/api-surface.txt); intentional changes rerun with --update and commit
python tools/check_api.py

echo "ci.sh: OK"
