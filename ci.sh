#!/usr/bin/env bash
# CI entrypoint: deps + tier-1 tests + headless runs of the shipped examples,
# so example drift fails the build fast.
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Best-effort dependency install; the repo degrades gracefully without the
# optional ones (zstandard -> zlib fallback, hypothesis -> skipped tests).
if [ "${CI_SKIP_INSTALL:-0}" != "1" ]; then
    python -m pip install --quiet pytest msgpack numpy jax zstandard hypothesis \
        || echo "ci.sh: pip install failed (offline?); using preinstalled deps"
fi

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== examples (headless) =="
python examples/quickstart.py
python examples/fever_screening.py

echo "== benchmarks: productivity claim =="
python -m benchmarks.run --only loc

echo "ci.sh: OK"
