"""Docs CI check: relative links must resolve, python fences must compile.

Two passes over the prose surface (``docs/*.md`` + ``README.md``):

1. **Link check** — every markdown link/image whose target is relative
   (not ``http(s)://``, ``mailto:``, or a pure ``#anchor``) must point at
   an existing file or directory, resolved against the page that links
   it.  Catches the classic docs rot: a module rename or file move that
   silently strands ``[bus.py](../src/repro/core/bus.py)``.

2. **Fence check** — every fenced ```` ```python ```` block in
   ``docs/*.md`` is extracted to a scratch file and run through
   ``python -m compileall``: examples in the docs must at least be valid
   syntax.  (README fences stay exempt — they show fragments mid-page —
   docs pages are held to the higher bar.)

Run from the repo root (CI does)::

    python tools/check_docs.py

Exit status 0 = clean; 1 = broken links and/or uncompilable fences, each
listed on stderr.  Stdlib only, so it runs on both CI matrix legs.
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parent.parent

#: Markdown inline links/images: ``[text](target)`` — title suffixes
#: (``(target "title")``) and angle brackets are stripped afterwards.
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_FENCE_RE = re.compile(r"^```python\s*$(.*?)^```\s*$",
                       re.MULTILINE | re.DOTALL)


def _pages() -> list[pathlib.Path]:
    return sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]


def check_links() -> list[str]:
    problems = []
    for page in _pages():
        if not page.exists():
            problems.append(f"{page.relative_to(REPO)}: page missing")
            continue
        for target in _LINK_RE.findall(page.read_text()):
            target = target.strip("<>")
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:            # pure in-page anchor
                continue
            resolved = (page.parent / path).resolve()
            if not resolved.exists():
                problems.append(
                    f"{page.relative_to(REPO)}: broken link -> {target}")
    return problems


def check_fences() -> list[str]:
    problems = []
    with tempfile.TemporaryDirectory(prefix="docs_fences_") as tmp:
        sources: list[tuple[pathlib.Path, str]] = []
        for page in sorted((REPO / "docs").glob("*.md")):
            for i, block in enumerate(_FENCE_RE.findall(page.read_text())):
                out = pathlib.Path(tmp) / f"{page.stem}_{i}.py"
                out.write_text(block)
                sources.append((page, str(out)))
        if not sources:
            return problems
        proc = subprocess.run(
            [sys.executable, "-m", "compileall", "-q", tmp],
            capture_output=True, text=True)
        if proc.returncode != 0:
            pages = sorted({str(p.relative_to(REPO)) for p, _ in sources})
            problems.append(
                f"python fence(s) failed to compile (from {', '.join(pages)})"
                f":\n{proc.stdout}{proc.stderr}")
    return problems


def main() -> int:
    problems = check_links() + check_fences()
    for p in problems:
        print(f"check_docs: {p}", file=sys.stderr)
    if problems:
        return 1
    n_pages = len(_pages())
    print(f"check_docs: OK ({n_pages} pages)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
