#!/usr/bin/env python
"""``datax check`` CLI shim — static dataflow analysis of a DataX app.

Usage (from the repo root)::

    PYTHONPATH=src python tools/datax_check.py examples/quickstart.py
    PYTHONPATH=src python tools/datax_check.py mypkg.pipelines:build_app --json

Thin wrapper over ``python -m repro.core.analyze`` so CI scripts and
developers have a stable entry point; see ``docs/diagnostics.md`` for the
DX code catalog and ``# datax: ignore[DXnnn] <reason>`` pragmas.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.analyze import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
