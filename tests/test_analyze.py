"""datax check: the build-time dataflow analyzer (repro.core.analyze).

Covers the seeded-bug fixture corpus (each fixture fires exactly its
planted DX code), the shipped examples (no error-severity findings), the
BarrierReason refactor (explanations match actual fusion behavior), the
three integration layers (strict build, CLI, operator/sidecar recording),
and the steal= plumbing that rode along.
"""
import importlib.util
import json
import logging
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core import App, Operator, connect
from repro.core.analyze import (Diagnostic, DiagnosticsError, Severity,
                                analyze_application, analyze_target,
                                has_errors, scan_ignores)
from repro.core.dsl import DSLError
from repro.core.fusion import (BarrierReason, consumer_counts, edge_barrier,
                               plan_segments, stream_barrier)
from repro.core.operator import OperatorError

REPO = Path(__file__).resolve().parent.parent
FIXTURES = sorted((REPO / "tests" / "fixtures" / "lint_apps").glob("dx*.py"))
EXAMPLES = REPO / "examples"
SRC = REPO / "src"


def _load(path: Path):
    sys.path.insert(0, str(path.parent))
    try:
        spec = importlib.util.spec_from_file_location(path.stem, path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod
    finally:
        sys.path.remove(str(path.parent))


def _codes(diags):
    return {d.code for d in diags}


def _analyze_obj(obj):
    out = []
    for _, application, taps in analyze_target(obj):
        out.extend(analyze_application(application, taps=taps))
    return out


# ---------------------------------------------------------------------------
# Seeded-bug corpus: each fixture fires exactly its planted code
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path", FIXTURES, ids=[p.stem for p in FIXTURES])
def test_fixture_fires_exactly_its_code(path):
    mod = _load(path)
    diags = _analyze_obj(mod.build_app)
    assert diags, f"{path.stem} produced no diagnostics"
    assert _codes(diags) == {mod.EXPECT}, (
        f"{path.stem}: expected only {mod.EXPECT}, got "
        f"{[d.format() for d in diags]}")


def test_fixture_corpus_covers_every_rule():
    from repro.core.analyze import RULES
    planted = {_load(p).EXPECT for p in FIXTURES}
    assert planted == set(RULES), (
        f"rules without a fixture: {set(RULES) - planted}")


def test_diagnostic_shape():
    mod = _load(FIXTURES[0])
    d = _analyze_obj(mod.build_app)[0]
    assert isinstance(d, Diagnostic)
    assert d.code == mod.EXPECT and d.severity is Severity.ERROR
    assert d.node.startswith(("stream/", "sensor/", "field/"))
    j = d.to_json()
    assert j["severity"] == "error" and j["app"] == "dx101"
    assert d.code in d.format() and d.fixit in d.format()


# ---------------------------------------------------------------------------
# Shipped examples stay error-free (the zero-false-positive gate)
# ---------------------------------------------------------------------------

def _example_paths():
    always = ["quickstart.py", "fever_screening.py", "stream_reuse.py",
              "replay_corpus.py"]
    return [EXAMPLES / n for n in always]


@pytest.mark.parametrize("path", _example_paths(),
                         ids=[p.stem for p in _example_paths()])
def test_examples_have_no_error_diagnostics(path):
    from repro.core.analyze import _discover
    mod = _load(path)
    targets = _discover(mod)
    assert targets, f"{path.name}: no checkable app discovered"
    for _, obj in targets:
        diags = _analyze_obj(obj)
        errors = [d.format() for d in diags
                  if d.severity >= Severity.ERROR]
        warnings = [d.format() for d in diags
                    if d.severity == Severity.WARNING]
        assert not errors, f"{path.name}: {errors}"
        assert not warnings, f"{path.name}: {warnings}"


def test_valid_dsl_graphs_are_error_free():
    """Property-style: representative *valid* graphs across the DSL surface
    (plain chain, keyed stateful, durable+replay, fused device chain,
    stolen keyed pool) carry no error-severity diagnostics."""
    def src(ctx, n=4):
        def g():
            for i in range(n):
                yield {"k": str(i % 2), "x": float(i)}
        return g()

    def sink_factory(ctx):
        return lambda s, p: None

    # plain chain into a gadget
    a1 = App("valid-chain")
    a1.driver(src, name="src")
    a1.actuator(sink_factory, name="sink")
    a1.sense("ev", "src").map(lambda p: p, name="m") >> a1.gadget(
        "g", "sink")
    # keyed stateful reduce, scaled, stealing
    a2 = App("valid-keyed")
    a2.driver(src, name="src")
    (a2.sense("ev", "src").key_by("k")
     .reduce(lambda acc, p: (acc or 0) + p["x"], name="sums")
     .scaled(instances=2, steal=True).tap())
    # durable feed + replaying consumer
    a3 = App("valid-durable")
    a3.driver(src, name="src")
    feed = a3.sense("ev", "src").durable(retention={"max_records": 64})
    feed.map(lambda p: p, name="late").replay(from_="earliest").tap()
    # fusible device chain with one max_batch declaration
    a4 = App("valid-device")
    a4.driver(src, name="src")
    (a4.sense("ev", "src")
     .map(lambda p: {"x": p["x"] * 2}, name="d1", device=True)
     .map(lambda p: {"x": p["x"] + 1}, name="d2", device=True)
     .scaled(max_batch=16).tap())
    for app in (a1, a2, a3, a4):
        diags = _analyze_obj(app)
        errs = [d.format() for d in diags if d.severity >= Severity.ERROR]
        assert not errs, f"{app.name}: {errs}"
        app.build(strict=True)  # and strict build agrees


# ---------------------------------------------------------------------------
# BarrierReason: explanations match actual fusion behavior
# ---------------------------------------------------------------------------

def _representative_app():
    app = App("barriers")

    def src(ctx, n=2):
        def g():
            for i in range(n):
                yield {"k": str(i), "x": float(i)}
        return g()

    def sink_factory(ctx):
        return lambda s, p: None

    app.driver(src, name="src")
    app.actuator(sink_factory, name="sink")
    # fusible pair, a tapped mid-chain subject (DEVICE-DEVICE edge that
    # cannot fuse), a keyed fusible pair, then a host exit into a gadget
    chain = (app.sense("ev", "src")
             .map(lambda p: p, name="d1", device=True)
             .map(lambda p: p, name="d2", device=True))
    chain.tap()
    tail = (chain.key_by("k")
            .map(lambda p: p, name="d3", device=True)
            .map(lambda p: p, name="d4", device=True))
    tail.map(lambda p: p, name="h1") >> app.gadget("g", "sink")
    return app


def test_barrier_reasons_match_fusion_behavior():
    app = _representative_app()
    application = app._compile()
    taps = frozenset(app._taps)
    aus = {a.name: a for a in application.analytics_units}
    streams = {s.name: s for s in application.streams}
    consumers = consumer_counts(application)
    segments = plan_segments(application, taps=taps)
    seg_of = {s.name: i for i, seg in enumerate(segments) for s in seg}
    # every adjacent stream->stream edge: fused together iff no barrier
    for down in application.streams:
        for subject in down.inputs:
            up = streams.get(subject)
            if up is None:
                continue
            fused_together = (seg_of.get(up.name) is not None
                              and seg_of.get(up.name) == seg_of.get(
                                  down.name))
            reason = stream_barrier(up, aus) or edge_barrier(
                up, down, aus, consumers=consumers, taps=taps)
            if fused_together:
                assert reason is None, (up.name, down.name, reason)
            else:
                assert reason is not None, (up.name, down.name)
    # the planted barriers come out by name
    by_edge = {}
    for down in application.streams:
        for subject in down.inputs:
            up = streams.get(subject)
            if up is not None:
                by_edge[(up.name, down.name)] = (
                    stream_barrier(up, aus) or edge_barrier(
                        up, down, aus, consumers=consumers, taps=taps))
    assert by_edge[("d1", "d2")] is None
    assert by_edge[("d2", "d3")] is BarrierReason.TAPPED
    assert by_edge[("d3", "d4")] is None  # uniformly keyed chain fuses
    assert by_edge[("d4", "h1")] is BarrierReason.NOT_DEVICE
    assert str(BarrierReason.TAPPED).startswith("TAPPED: ")
    assert BarrierReason.TAPPED.explain


def test_dx201_names_the_barrier():
    app = _representative_app()
    diags = [d for d in _analyze_obj(app) if d.code == "DX201"]
    assert len(diags) == 1            # only the d2 -> d3 edge needs a story
    assert "'d2' -> 'd3'" in diags[0].message
    assert "TAPPED" in diags[0].message
    # fused-together pairs and host edges are not second-guessed
    assert diags[0].node == "stream/d3"


# ---------------------------------------------------------------------------
# Integration layer 1: App.build(strict=)
# ---------------------------------------------------------------------------

def _app_with_error():
    from repro.core import ShardSpec, StreamSchema
    app = App("strict-bad")

    def src(ctx):
        def g():
            yield {"x": 1.0}
        return g()

    # rank-mismatched ShardSpec: DX301 (error severity), but still a graph
    # the legacy validators accept
    bad = StreamSchema.device(x=((8, 8), "float32", ShardSpec(("data",))))
    app.driver(src, name="src", emits=bad)
    app.sense("ev", "src").map(lambda p: p, name="m").tap()
    return app


def test_build_strict_raises_on_error_diagnostics():
    with pytest.raises(DiagnosticsError) as ei:
        _app_with_error().build(strict=True)
    assert any(d.code == "DX301" for d in ei.value.diagnostics)


def test_build_default_logs_and_succeeds(caplog):
    with caplog.at_level(logging.WARNING, logger="repro.core.analyze"):
        application = _app_with_error().build()
    assert application.streams  # built anyway
    assert any("DX301" in r.message for r in caplog.records)


def test_build_clean_app_is_quiet(caplog):
    app = App("strict-clean")

    def src(ctx):
        def g():
            yield {"x": 1.0}
        return g()

    app.driver(src, name="src")
    app.sense("ev", "src").map(lambda p: p, name="m").tap()
    with caplog.at_level(logging.WARNING, logger="repro.core.analyze"):
        app.build(strict=True)
    assert not caplog.records


# ---------------------------------------------------------------------------
# Integration layer 2: the CLI
# ---------------------------------------------------------------------------

def _run_cli(*args):
    env = dict(os.environ, PYTHONPATH=str(SRC))
    return subprocess.run(
        [sys.executable, "-m", "repro.core.analyze", *args],
        capture_output=True, text=True, cwd=REPO, env=env)


def test_cli_reports_errors_with_exit_code():
    bad = REPO / "tests" / "fixtures" / "lint_apps" / \
        "dx104_replay_nondurable.py"
    proc = _run_cli(str(bad))
    assert proc.returncode == 1
    assert "DX104" in proc.stdout


def test_cli_clean_module_exits_zero():
    proc = _run_cli(str(EXAMPLES / "quickstart.py"))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_cli_json_output():
    bad = REPO / "tests" / "fixtures" / "lint_apps" / \
        "dx104_replay_nondurable.py"
    proc = _run_cli(str(bad), "--json")
    report = json.loads(proc.stdout)
    assert report["errors"] == 1
    codes = [d["code"] for r in report["reports"]
             for d in r["diagnostics"]]
    assert codes == ["DX104"]


def test_cli_pragma_suppresses(tmp_path):
    src_file = (REPO / "tests" / "fixtures" / "lint_apps" /
                "dx104_replay_nondurable.py")
    common = (REPO / "tests" / "fixtures" / "lint_apps" / "_common.py")
    patched = ("# datax: ignore[DX104] fixture exercises the pragma path\n"
               + src_file.read_text())
    (tmp_path / "suppressed.py").write_text(patched)
    (tmp_path / "_common.py").write_text(common.read_text())
    proc = _run_cli(str(tmp_path / "suppressed.py"))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ignoring DX104" in proc.stdout


def test_scan_ignores():
    text = ("x = 1  # datax: ignore[DX104] vetted\n"
            "# datax: ignore[DX301]\n# datax ignore[DX999]\n")
    assert scan_ignores(text) == {"DX104", "DX301"}


# ---------------------------------------------------------------------------
# Integration layer 3: deploy-time recording (operator + sidecar REST analog)
# ---------------------------------------------------------------------------

def test_deploy_records_diagnostics_on_operator_and_sidecar():
    app = App("flagged")

    def src(ctx, n=1):
        def g():
            for i in range(n):
                yield {"x": float(i)}
        return g()

    app.driver(src, name="src")
    app.sense("ev", "src").map(lambda p: p, name="orphan")  # DX401 warning
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        recorded = op.diagnostics()
        assert "flagged" in recorded
        codes = [d["code"] for d in recorded["flagged"]]
        assert "DX401" in codes
        summary = op.describe()["diagnostics"]["flagged"]
        assert summary["warning"] >= 1 and summary["error"] == 0
        sidecars = op.executor.instances_of("orphan")
        assert sidecars
        entries = sidecars[0].sidecar.metrics()["diagnostics"]
        assert {"code": "DX401", "severity": "warning"} in entries


def test_deploy_clean_app_records_empty():
    app = App("clean-deploy")

    def src(ctx, n=1):
        def g():
            for i in range(n):
                yield {"x": float(i)}
        return g()

    app.driver(src, name="src")
    app.sense("ev", "src").map(lambda p: p, name="m").tap()
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        assert op.diagnostics() == {"clean-deploy": []}
        assert not has_errors([])


# ---------------------------------------------------------------------------
# Satellite: steal= plumbing (DSL -> spec -> fusion -> subscription)
# ---------------------------------------------------------------------------

def test_scaled_steal_reaches_the_queue_group():
    app = App("steal-app")

    def src(ctx, n=1):
        def g():
            for i in range(n):
                yield {"k": str(i), "x": float(i)}
        return g()

    app.driver(src, name="src")
    (app.sense("ev", "src").key_by("k")
     .map(lambda p: p, name="routed")
     .scaled(instances=2, steal=True).tap())
    application = app.build()
    spec = next(s for s in application.streams if s.name == "routed")
    assert spec.steal and spec.delivery == "keyed"
    with connect(start=False) as op:
        application.deploy(op, start_sensors=False)
        m = op.executor.instances_of("routed")[0].sidecar.metrics()
        assert m["groups"]["ev"]["steal_enabled"] is True


def test_steal_survives_fusion():
    app = App("steal-fused")

    def src(ctx, n=1):
        def g():
            for i in range(n):
                yield {"x": float(i)}
        return g()

    app.driver(src, name="src")
    # steal lives on the segment ENTRY stream: the fused unit consumes the
    # entry's input subject, so the entry's pool policy is what carries over
    entry = (app.sense("ev", "src")
             .map(lambda p: p, name="d1", device=True)
             .scaled(steal=True))
    entry.map(lambda p: p, name="d2", device=True).tap()
    application = app.build()
    fused = next(s for s in application.streams if s.name == "d2")
    au = next(a for a in application.analytics_units
              if a.name == fused.analytics_unit)
    assert au.fused_stages          # the chain really fused
    assert fused.steal is True      # entry's steal carried onto the unit


def test_steal_rejected_for_broadcast():
    app = App("steal-bad")

    def src(ctx, n=1):
        def g():
            for i in range(n):
                yield {"x": float(i)}
        return g()

    app.driver(src, name="src")
    handle = app.sense("ev", "src").map(lambda p: p, name="m")
    with pytest.raises(DSLError, match="steal"):
        handle.scaled(delivery="broadcast", steal=True)
    # and the operator-level validation agrees for raw v1 specs
    from repro.core import AnalyticsUnitSpec, StreamSpec
    op = Operator()
    try:
        op.register_analytics_unit(AnalyticsUnitSpec(
            name="pass", logic=lambda ctx: lambda s, p: p))
        with pytest.raises(OperatorError, match="steal"):
            op.create_stream(StreamSpec(
                name="bad", analytics_unit="pass", inputs=(),
                delivery="broadcast", steal=True))
    finally:
        op.shutdown()
