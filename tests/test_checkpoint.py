"""Checkpointing: atomic commit, checksums, retention, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointError, CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,), jnp.bfloat16)},
            "opt": {"m": jnp.ones((8, 16)), "count": jnp.int32(5)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(3, state, blocking=True)
    restored, manifest = mgr.restore(jax.eval_shape(lambda: state))
    assert manifest["step"] == 3
    np.testing.assert_array_equal(restored["params"]["w"],
                                  state["params"]["w"])
    assert restored["params"]["b"].dtype == jnp.bfloat16
    assert int(restored["opt"]["count"]) == 5


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    mgr.wait()
    assert mgr.latest_step() == 1


def test_atomicity_torn_write_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(), blocking=True)
    # simulate a crash mid-write of step 2: tmp dir exists, no manifest
    torn = tmp_path / "step_00000002.tmp"
    torn.mkdir()
    (torn / "shard_00000.dxckpt").write_bytes(b"partial garbage")
    assert mgr.latest_step() == 1  # torn write invisible
    restored, manifest = mgr.restore(jax.eval_shape(lambda: _state()))
    assert manifest["step"] == 1


def test_checksum_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(), blocking=True)
    shard = tmp_path / "step_00000001" / "shard_00000.dxckpt"
    blob = bytearray(shard.read_bytes())
    blob[len(blob) // 2] ^= 0xFF
    shard.write_bytes(bytes(blob))
    with pytest.raises(CheckpointError):
        mgr.restore(jax.eval_shape(lambda: _state()))


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_elastic_restore_new_sharding(tmp_path):
    """Restore re-lays-out onto a different (here trivial) mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(1, state, blocking=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), state)
    restored, _ = mgr.restore(jax.eval_shape(lambda: state),
                              shardings=shardings)
    np.testing.assert_array_equal(restored["params"]["w"],
                                  state["params"]["w"])
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())
