"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core.bus import decode_payload, encode_payload
from repro.core.schema import ConfigSchema, FieldSpec, StreamSchema
from repro.core.sdk import LogicContext
from repro.data.pipeline import packer_au
from repro.models.moe import moe_capacity, moe_group_shape
from repro.configs import get_smoke_config


# ---------------------------------------------------------------------------
# Packer: token conservation + exact sequence lengths
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=200), min_size=1,
                max_size=30),
       st.integers(min_value=4, max_value=64))
def test_packer_conserves_tokens(doc_lens, seq_len):
    ctx = LogicContext({"seq_len": seq_len})
    process = packer_au(ctx)
    emitted = []
    total_in = 0
    counter = 0
    for n in doc_lens:
        doc = np.arange(counter, counter + n, dtype=np.int32)
        counter += n
        total_in += n
        out = process("docs", {"tokens": doc}) or []
        emitted.extend(out)
    # every emitted sequence has exactly seq_len+1 tokens
    for seq in emitted:
        assert len(seq["tokens"]) == seq_len + 1
    # conservation: emitted + leftover == input, in order, no duplication
    flat = np.concatenate([s["tokens"] for s in emitted]) if emitted else \
        np.array([], np.int32)
    assert len(flat) == (total_in // (seq_len + 1)) * (seq_len + 1)
    np.testing.assert_array_equal(flat, np.arange(len(flat), dtype=np.int32))


# ---------------------------------------------------------------------------
# Wire format: msgpack+numpy round-trip is the identity
# ---------------------------------------------------------------------------

_scalars = st.one_of(st.integers(min_value=-2**40, max_value=2**40),
                     st.floats(allow_nan=False, allow_infinity=False,
                               width=32),
                     st.text(max_size=20), st.booleans(),
                     st.binary(max_size=40))


@settings(max_examples=50, deadline=None)
@given(st.dictionaries(st.text(min_size=1, max_size=8), _scalars,
                       max_size=6),
       st.integers(min_value=0, max_value=3))
def test_wire_roundtrip_identity(payload, arr_rank):
    if arr_rank:
        shape = tuple(np.random.randint(1, 4, arr_rank))
        payload["__arr"] = np.random.randn(*shape).astype(np.float32)
    out = decode_payload(encode_payload(payload))
    assert set(out) == set(payload)
    for k, v in payload.items():
        if isinstance(v, np.ndarray):
            np.testing.assert_array_equal(out[k], v)
        elif isinstance(v, float):
            assert out[k] == v or abs(out[k] - v) < 1e-6
        else:
            assert out[k] == v


# ---------------------------------------------------------------------------
# ConfigSchema: accepts_configs_of is consistent with validate
# ---------------------------------------------------------------------------

_type_names = st.sampled_from(["int", "float", "str", "bool"])
_sample_values = {"int": 3, "float": 1.5, "str": "x", "bool": True}


@st.composite
def _schema(draw):
    fields = {}
    for name in draw(st.lists(st.sampled_from("abcde"), unique=True,
                              max_size=4)):
        t = draw(_type_names)
        required = draw(st.booleans())
        fields[name] = (t, ConfigSchema.REQUIRED if required
                        else _sample_values[t])
    return ConfigSchema(fields=fields)


@settings(max_examples=60, deadline=None)
@given(_schema(), _schema())
def test_schema_compat_soundness(old, new):
    """If new.accepts_configs_of(old), every old-valid config (built from
    old's required fields + any optional subset) must validate under new,
    up to unknown-field pruning (the operator prunes on upgrade)."""
    if not new.accepts_configs_of(old):
        return
    # minimal old config: required fields only
    cfg = {name: _sample_values[t] for name, (t, d) in old.fields.items()
           if d is ConfigSchema.REQUIRED}
    pruned = {k: v for k, v in cfg.items() if k in new.fields}
    new.validate(pruned)  # must not raise


# ---------------------------------------------------------------------------
# StreamSchema.accepts: reflexive; accepted payloads validate
# ---------------------------------------------------------------------------

@st.composite
def _stream_schema(draw):
    fields = {}
    for name in draw(st.lists(st.sampled_from("xyz"), unique=True,
                              min_size=1, max_size=3)):
        kind = draw(st.sampled_from(["int", "float", "str", "ndarray"]))
        fields[name] = FieldSpec(kind=kind)
    return StreamSchema(fields=fields)


@settings(max_examples=40, deadline=None)
@given(_stream_schema())
def test_stream_schema_reflexive(schema):
    assert schema.accepts(schema)


# ---------------------------------------------------------------------------
# MoE grouping: group shape divides tokens; capacity >= perfect balance
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 20))
def test_moe_group_shape_divides(T):
    g, s = moe_group_shape(T)
    assert g * s == T and s >= 1


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=8, max_value=4096))
def test_moe_capacity_sufficient(group):
    cfg = get_smoke_config("grok-1-314b")
    c = moe_capacity(group, cfg)
    m = cfg.moe
    assert c * m.num_experts >= group * m.top_k  # >= perfectly-balanced load
