"""Launch-path integration: dry-run cell + elastic re-mesh, in subprocesses
(device-count changes require fresh jax processes)."""
import os
import subprocess
import sys
import textwrap

import pytest

_ENV = {**os.environ, "PYTHONPATH": "src"}
_CWD = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, timeout: int = 560):
    return subprocess.run([sys.executable, "-c", textwrap.dedent(prog)],
                          capture_output=True, text=True, timeout=timeout,
                          env=_ENV, cwd=_CWD)


@pytest.mark.slow
def test_dryrun_cell_on_production_mesh(tmp_path):
    """The flagship deliverable in miniature: one real cell, 512 fake
    devices, lower+compile+roofline — exactly what dryrun --all does."""
    prog = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_cell
        r = run_cell("mamba2-370m", "decode_32k", multi_pod=False,
                     out_dir={str(tmp_path)!r})
        assert r["ok"] and r["flops_per_device"] > 0
        assert r["wire_bytes_per_device"] >= 0
        assert r["bottleneck"] in ("compute", "memory", "collective")
        r2 = run_cell("mamba2-370m", "decode_32k", multi_pod=True,
                      out_dir={str(tmp_path)!r})
        assert r2["chips"] == 512 and r["chips"] == 256
        print("OK", r["bottleneck"], r2["chips"])
    """
    res = _run(prog)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout


@pytest.mark.slow
def test_elastic_shrink_mesh_resumes_training(tmp_path):
    """Node-loss drill: train on a (4,1) mesh, checkpoint, 'lose' two
    devices, rebuild a (2,1) mesh, restore, keep training — losses finite
    and state identical across the re-shard."""
    prog = f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, numpy as np
        from repro.configs import get_smoke_config
        from repro.configs.base import RunConfig
        from repro import models
        from repro.train import optimizer as opt, steps
        from repro.train.checkpoint import CheckpointManager
        from repro.train.fault import ElasticController

        cfg = get_smoke_config("qwen3-14b")
        run = RunConfig(attention_impl="chunked", attention_chunk=16,
                        remat="none", learning_rate=1e-3, warmup_steps=1)
        key = jax.random.PRNGKey(0)
        batch = {{"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab)}}
        bshape = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch)

        # phase 1: 4-device mesh
        mesh4 = jax.make_mesh((4, 1), ("data", "model"))
        f4, _ = steps.jit_train_step(cfg, run, mesh4, bshape)
        params = models.init(key, cfg)
        state = opt.init_opt_state(params, run)
        params, state, m1 = f4(params, state, batch)
        mgr = CheckpointManager({str(tmp_path)!r})
        mgr.save(1, {{"params": params, "opt": state}}, blocking=True)

        # phase 2: two devices "lost" -> (2,1) mesh, restore, continue
        ec = ElasticController(cfg, run)
        mesh2 = ec.build_mesh(jax.devices()[:2], model_axis=1)
        like = {{"params": jax.eval_shape(lambda: params),
                "opt": jax.eval_shape(lambda: state)}}
        restored, manifest = mgr.restore(like)
        assert manifest["step"] == 1
        f2, _ = steps.jit_train_step(cfg, run, mesh2, bshape)
        p2, s2, m2 = f2(restored["params"], restored["opt"], batch)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert np.isfinite(l1) and np.isfinite(l2) and l2 < l1 + 1.0
        print("OK", l1, l2)
    """
    res = _run(prog)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout


@pytest.mark.slow
def test_hlo_collective_parse_multi_device():
    """Sharded matmul on a (1,4) mesh must surface an all-reduce whose wire
    bytes match the ring model 2(n-1)/n * bytes."""
    prog = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.roofline.hlo_cost import analyze_hlo

        mesh = jax.make_mesh((1, 4), ("data", "model"))
        def f(x, w):
            return x @ w
        xs = NamedSharding(mesh, P(None, "model"))
        ws = NamedSharding(mesh, P("model", None))
        c = jax.jit(f, in_shardings=(xs, ws),
                    out_shardings=NamedSharding(mesh, P())).lower(
            jax.ShapeDtypeStruct((256, 256), jnp.float32),
            jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
        t = analyze_hlo(c.as_text())
        expect = 2 * (4 - 1) / 4 * 256 * 256 * 4
        assert t.collective_bytes.get("all-reduce", 0) == expect, t.collective_bytes
        print("OK", t.collective_bytes)
    """
    res = _run(prog)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout
