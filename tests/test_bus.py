"""MessageBus: registration, authz, schema enforcement, drop policy, wire."""
import threading

import numpy as np
import pytest

from repro.core import (FieldSpec, MessageBus, StreamSchema, Unauthorized,
                        UnknownSubject, drain)
from repro.core.bus import decode_payload, encode_payload


@pytest.fixture
def bus():
    b = MessageBus()
    b.register_subject("s1", StreamSchema.of(x=FieldSpec("int")))
    return b


def test_publish_requires_registration(bus):
    tok = bus.issue_token("t", ["s1"])
    with pytest.raises(UnknownSubject):
        bus.publish("nope", {"x": 1}, token=tok)


def test_publish_requires_authorization(bus):
    tok = bus.issue_token("t", ["other"])
    bus.register_subject("other")
    with pytest.raises(Unauthorized):
        bus.publish("s1", {"x": 1}, token=tok)
    with pytest.raises(Unauthorized):
        bus.publish("s1", {"x": 1}, token="forged-token")


def test_schema_enforced(bus):
    tok = bus.issue_token("t", ["s1"])
    with pytest.raises(TypeError):
        bus.publish("s1", {"x": "not-an-int"}, token=tok)
    with pytest.raises(KeyError):
        bus.publish("s1", {}, token=tok)
    bus.publish("s1", {"x": 3}, token=tok)  # ok


def test_pubsub_roundtrip(bus):
    tok = bus.issue_token("t", ["s1"])
    sub = bus.subscribe("s1", token=tok)
    for i in range(10):
        bus.publish("s1", {"x": i}, token=tok)
    msgs = drain(sub, 10)
    assert [m.payload["x"] for m in msgs] == list(range(10))


def test_drop_oldest_policy(bus):
    tok = bus.issue_token("t", ["s1"])
    sub = bus.subscribe("s1", token=tok, maxsize=4)
    for i in range(10):
        bus.publish("s1", {"x": i}, token=tok)
    msgs = drain(sub, 4)
    assert [m.payload["x"] for m in msgs] == [6, 7, 8, 9]  # newest kept
    assert sub.dropped == 6


def test_wire_serialization_ndarray():
    payload = {"a": np.arange(12, dtype=np.int32).reshape(3, 4),
               "b": "text", "c": 4.5, "d": b"raw"}
    out = decode_payload(encode_payload(payload))
    np.testing.assert_array_equal(out["a"], payload["a"])
    assert out["b"] == "text" and out["c"] == 4.5 and out["d"] == b"raw"


def test_wire_subscription(bus):
    b = MessageBus()
    b.register_subject("w", StreamSchema.of(
        arr=FieldSpec("ndarray", shape=(-1,), dtype="float32")))
    tok = b.issue_token("t", ["w"])
    sub = b.subscribe("w", token=tok, wire=True)
    arr = np.linspace(0, 1, 5, dtype=np.float32)
    b.publish("w", {"arr": arr}, token=tok)
    msg = sub.next(timeout=2)
    np.testing.assert_array_equal(msg.payload["arr"], arr)


def test_concurrent_publishers(bus):
    tok = bus.issue_token("t", ["s1"])
    sub = bus.subscribe("s1", token=tok, maxsize=4096)
    n_threads, per = 8, 50

    def work(base):
        for i in range(per):
            bus.publish("s1", {"x": base + i}, token=tok)

    threads = [threading.Thread(target=work, args=(k * 1000,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    msgs = drain(sub, n_threads * per)
    assert len({m.seq for m in msgs}) == n_threads * per


def test_unregister_closes_subscribers(bus):
    tok = bus.issue_token("t", ["s1"])
    sub = bus.subscribe("s1", token=tok)
    bus.unregister_subject("s1")
    assert sub.next(timeout=0.2) is None
    assert sub.closed
