"""Operator coherence rules (paper §4) + lifecycle + reuse (§3)."""
import time

import pytest

from repro.core import (AnalyticsUnitSpec, CoherenceError, ConfigSchema,
                        DriverSpec, FieldSpec, Operator, OperatorError,
                        SensorSpec, StreamSchema, StreamSpec, drain)


def counter_driver(ctx):
    delay = float(ctx.config.get("delay", 0.0))

    def gen():
        for i in range(int(ctx.config.get("n", 100))):
            if not ctx.running:
                return
            if delay:
                time.sleep(delay)
            yield {"value": i}
    return gen()


def doubler(ctx):
    scale = int(ctx.config.get("scale", 2))
    return lambda stream, payload: {"value": payload["value"] * scale}


INT_SCHEMA = StreamSchema.of(value=FieldSpec("int"))


@pytest.fixture
def op():
    o = Operator(reconcile_interval_s=0.05)
    o.register_driver(DriverSpec(
        name="counter", logic=counter_driver,
        config_schema=ConfigSchema.of(n=("int", 100), delay=("float", 0.0)),
        output_schema=INT_SCHEMA))
    o.register_analytics_unit(AnalyticsUnitSpec(
        name="doubler", logic=doubler,
        config_schema=ConfigSchema.of(scale=("int", 2)),
        output_schema=INT_SCHEMA))
    yield o
    o.shutdown()


def test_sensor_requires_installed_driver(op):
    with pytest.raises(CoherenceError):
        op.register_sensor(SensorSpec(name="s", driver="missing"))


def test_sensor_config_validated(op):
    with pytest.raises(TypeError):
        op.register_sensor(SensorSpec(name="s", driver="counter",
                                      config={"n": "many"}))
    with pytest.raises(KeyError):
        op.register_sensor(SensorSpec(name="s", driver="counter",
                                      config={"unknown": 1}))


def test_stream_requires_au_and_inputs(op):
    with pytest.raises(CoherenceError):
        op.create_stream(StreamSpec(name="d", analytics_unit="missing",
                                    inputs=()))
    with pytest.raises(CoherenceError):
        op.create_stream(StreamSpec(name="d", analytics_unit="doubler",
                                    inputs=("nope",)))


def test_delete_in_use_refused(op):
    op.register_sensor(SensorSpec(name="nums", driver="counter",
                                  config={"n": 5}))
    op.create_stream(StreamSpec(name="doubled", analytics_unit="doubler",
                                inputs=("nums",)))
    with pytest.raises(CoherenceError):
        op.delete_driver("counter")          # sensor uses it
    with pytest.raises(CoherenceError):
        op.delete_analytics_unit("doubler")  # stream uses it
    with pytest.raises(CoherenceError):
        op.delete_sensor("nums")             # feeds 'doubled'
    # correct teardown order succeeds
    op.delete_stream("doubled")
    op.delete_sensor("nums")
    op.delete_analytics_unit("doubler")
    op.delete_driver("counter")


def test_pipeline_delivers(op):
    op.register_sensor(SensorSpec(name="nums", driver="counter",
                                  config={"n": 8}), start=False)
    op.create_stream(StreamSpec(name="doubled", analytics_unit="doubler",
                                inputs=("nums",), config={"scale": 3}))
    sub = op.subscribe("doubled")
    op.start_pending_sensors()
    vals = sorted(m.payload["value"] for m in drain(sub, 8))
    assert vals == [3 * i for i in range(8)]


def test_upgrade_compatible_schema_cascades(op):
    op.register_sensor(SensorSpec(name="nums", driver="counter",
                                  config={"n": 50}))
    op.create_stream(StreamSpec(name="doubled", analytics_unit="doubler",
                                inputs=("nums",)))
    # v2 adds an optional field -> compatible
    op.upgrade_analytics_unit(AnalyticsUnitSpec(
        name="doubler", logic=doubler, version=2,
        config_schema=ConfigSchema.of(scale=("int", 2), bias=("int", 0)),
        output_schema=INT_SCHEMA))
    assert op.describe()["analytics_units"]["doubler"] == 2
    assert any(e[1] == "upgrade" for e in op.events)


def test_upgrade_incompatible_schema_refused(op):
    op.register_sensor(SensorSpec(name="nums", driver="counter"))
    op.create_stream(StreamSpec(name="doubled", analytics_unit="doubler",
                                inputs=("nums",)))
    bad = AnalyticsUnitSpec(
        name="doubler", logic=doubler, version=2,
        config_schema=ConfigSchema.of(
            scale=("str", ConfigSchema.REQUIRED)),   # type change + required
        output_schema=INT_SCHEMA)
    with pytest.raises(CoherenceError):
        op.upgrade_analytics_unit(bad)
    assert op.describe()["analytics_units"]["doubler"] == 1


def test_upgrade_with_converter(op):
    op.register_sensor(SensorSpec(name="nums", driver="counter"))
    op.create_stream(StreamSpec(name="doubled", analytics_unit="doubler",
                                inputs=("nums",), config={"scale": 4}))
    v2 = AnalyticsUnitSpec(
        name="doubler", logic=doubler, version=2,
        config_schema=ConfigSchema.of(factor=("int", ConfigSchema.REQUIRED)),
        output_schema=INT_SCHEMA)
    # converter fails -> refused (paper: accept only if it succeeds for ALL)
    with pytest.raises(CoherenceError):
        op.upgrade_analytics_unit(v2, converter=lambda c: 1 / 0)
    # working converter -> accepted
    op.upgrade_analytics_unit(
        v2, converter=lambda c: {"factor": c.get("scale", 2)})
    assert op.describe()["analytics_units"]["doubler"] == 2


def test_version_must_increase(op):
    with pytest.raises(OperatorError):
        op.upgrade_analytics_unit(AnalyticsUnitSpec(
            name="doubler", logic=doubler, version=1,
            output_schema=INT_SCHEMA))


def test_crash_restart(op):
    crashes = {"n": 0}

    def flaky(ctx):
        def process(stream, payload):
            if payload["value"] == 3 and crashes["n"] == 0:
                crashes["n"] += 1
                raise RuntimeError("boom")
            return {"value": payload["value"]}
        return process

    op.register_analytics_unit(AnalyticsUnitSpec(
        name="flaky", logic=flaky, output_schema=INT_SCHEMA))
    # paced source: the restart happens mid-stream, so the pipeline keeps
    # flowing after the crash (messages during the dead window are lossy)
    op.register_sensor(SensorSpec(name="nums", driver="counter",
                                  config={"n": 40, "delay": 0.05}),
                       start=False)
    op.create_stream(StreamSpec(name="out", analytics_unit="flaky",
                                inputs=("nums",)))
    op.start()
    sub = op.subscribe("out")
    op.start_pending_sensors()
    got = []
    deadline = time.monotonic() + 15
    while len(got) < 20 and time.monotonic() < deadline:
        m = sub.next(timeout=0.5)
        if m:
            got.append(m.payload["value"])
    assert crashes["n"] == 1
    assert len(got) >= 20                      # kept flowing after restart
    assert any(e[1] in ("restart", "crash") for e in op.events)


def test_stream_reuse_across_apps(op):
    """§3: a second app subscribes to the first app's registered stream."""
    op.register_sensor(SensorSpec(name="nums", driver="counter",
                                  config={"n": 12}), start=False)
    op.create_stream(StreamSpec(name="doubled", analytics_unit="doubler",
                                inputs=("nums",)))
    assert "doubled" in op.registered_streams()
    # app 2 reuses 'doubled' without touching app 1
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="plus1", logic=lambda ctx: (
            lambda s, p: {"value": p["value"] + 1}),
        output_schema=INT_SCHEMA))
    op.create_stream(StreamSpec(name="plussed", analytics_unit="plus1",
                                inputs=("doubled",)))
    sub = op.subscribe("plussed")
    op.start_pending_sensors()
    vals = sorted(m.payload["value"] for m in drain(sub, 12))
    assert vals == sorted(2 * i + 1 for i in range(12))
