"""Tier-1 collection config: keep the suite runnable on minimal deps.

The jax-dependent modules (kernels, models, serve/train stack) are skipped
wholesale when jax is not importable — the CI "minimal" matrix leg runs the
platform core (bus/operator/DSL/fusion-fallback) without them.
"""
_NEEDS_JAX = [
    "test_checkpoint.py",
    "test_fault.py",
    "test_kernels.py",
    "test_launch.py",
    "test_mesh.py",
    "test_models.py",
    "test_property.py",
    "test_serve.py",
    "test_sharding.py",
    "test_train.py",
]

try:  # a real import (not find_spec): a present-but-broken jax must also skip
    import jax  # noqa: F401
    collect_ignore: list = []
except Exception:
    collect_ignore = list(_NEEDS_JAX)
