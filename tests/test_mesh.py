"""Mesh-sharded fused execution (PR 8): sharding, residency, autotune.

The multi-device half runs in a SUBPROCESS (benchmarks/mesh_worker.py with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``) because the device
count must be fixed before jax initializes — this test process already
imported jax with one device.  The single-process half exercises the same
machinery in-process: burst PartitionSpecs, ResidentArray reuse rules, the
burst autotuner, and bit-identity of the sharded program on a 1-device mesh.
"""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import ShardSpec, StreamSchema  # noqa: E402
from repro.core import fusion  # noqa: E402
from repro.core.fusion import (AUTOTUNE_STREAK, FusedStage,  # noqa: E402
                               ResidentArray, _resident_burst,
                               _to_device_batched, make_fused_logic)
from repro.core.sdk import LogicContext  # noqa: E402
from repro.distributed.sharding import burst_spec  # noqa: E402
from repro.kernels.ops import (jit_chain_batched,  # noqa: E402
                               jit_chain_sharded)

_REPO = pathlib.Path(__file__).resolve().parent.parent
WORKER = _REPO / "benchmarks" / "mesh_worker.py"

D = 16


def _stage_fn(w):
    return lambda p: {"x": jnp.tanh(p["x"] @ w)}


def _fused_process(n_stages=2, schema=None, max_batch=None, resident=False):
    rng = np.random.default_rng(0)
    stages = []
    for i in range(n_stages):
        fn = _stage_fn(rng.standard_normal((D, D)).astype(np.float32))

        def factory(ctx, fn=fn):
            return lambda stream, payload: fn(payload)

        stages.append(FusedStage(au_name=f"au{i}", stream_name=f"s{i}",
                                 factory=factory, config={}, kind="map",
                                 pure_fn=fn))
    if schema is None:
        schema = StreamSchema.device(x=((4, D), "float32"))
    ctx = LogicContext({}, db=None, instance_id="test")
    return make_fused_logic(stages, schema, max_batch=max_batch,
                            resident=resident)(ctx)


@pytest.fixture
def jit_always(monkeypatch):
    monkeypatch.setenv("DATAX_FUSION_JIT", "always")


def _payloads(n, rows=4):
    rng = np.random.default_rng(1)
    return [{"x": rng.standard_normal((rows, D)).astype(np.float32)}
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Multi-device: subprocess with 4 fake host devices
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_execution_on_four_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(_REPO / "src")
    env.pop("DATAX_FUSION_MESH", None)
    proc = subprocess.run(
        [sys.executable, str(WORKER), "--rounds", "2"],
        env=env, cwd=str(_REPO), capture_output=True, text=True, timeout=570)
    assert proc.returncode == 0, proc.stderr
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    assert data["devices"] == 4
    assert data["mesh_devices"] == 4
    assert data["sharded_bursts"] > 0      # the mesh path actually ran
    assert data["bit_identical"] is True   # vs single-device AND host chain


# ---------------------------------------------------------------------------
# fusion_mesh gating
# ---------------------------------------------------------------------------

def test_fusion_mesh_single_device_is_none():
    # this process sees one CPU device -> no mesh, no sharded path
    if jax.local_device_count() != 1:
        pytest.skip("test process has multiple devices")
    assert fusion.fusion_mesh() is None
    assert fusion.mesh_axis_names() == ()


def test_fusion_mesh_env_disable(monkeypatch):
    monkeypatch.setenv("DATAX_FUSION_MESH", "0")
    assert fusion.fusion_mesh() is None


# ---------------------------------------------------------------------------
# burst_spec: schema hints -> PartitionSpecs
# ---------------------------------------------------------------------------

def _mesh1():
    from jax.sharding import Mesh
    return Mesh(np.array(jax.local_devices()[:1]), ("data",))


def test_burst_spec_leading_batch_axis():
    from jax.sharding import PartitionSpec as P
    mesh = _mesh1()
    assert burst_spec(mesh, 8, (4, D), None) == P(("data",), None, None)
    # hint axes the mesh doesn't have replicate silently
    assert burst_spec(mesh, 8, (4, D), ShardSpec(("model", None))) \
        == P(("data",), None, None)
    # the data axis is spent on the batch dim -> not reused on trailing dims
    assert burst_spec(mesh, 8, (4, D), ShardSpec(("data", None))) \
        == P(("data",), None, None)


def test_burst_spec_divisibility():
    from jax.sharding import PartitionSpec as P
    mesh = _mesh1()   # axis size 1 divides everything
    assert burst_spec(mesh, 7, (3,), None) == P(("data",), None)


# ---------------------------------------------------------------------------
# jit_chain_sharded: bit-identity on a 1-device mesh
# ---------------------------------------------------------------------------

def test_jit_chain_sharded_matches_batched():
    rng = np.random.default_rng(2)
    w = rng.standard_normal((D, D)).astype(np.float32)
    chain = [("map", _stage_fn(w))]
    batched = jit_chain_batched(chain)
    sharded = jit_chain_sharded(chain, _mesh1(), {})
    x = rng.standard_normal((8, 4, D)).astype(np.float32)
    out_b, keep_b = batched({"x": jnp.asarray(x)})
    out_s, keep_s = sharded({"x": x})
    assert np.array_equal(np.asarray(out_b["x"]), np.asarray(out_s["x"]))
    assert np.array_equal(np.asarray(keep_b), np.asarray(keep_s))


# ---------------------------------------------------------------------------
# ResidentArray: wrap/reuse rules
# ---------------------------------------------------------------------------

def test_resident_array_wrap_and_derivation():
    dev = jnp.zeros((4, 3))
    row = ResidentArray.wrap(np.ones(3), dev, 1)
    assert isinstance(row, np.ndarray)
    assert row._datax_dev is dev and row._datax_row == 1
    # views/slices/copies must NOT inherit residency
    assert row[1:]._datax_dev is None
    assert row.copy()._datax_dev is None
    assert (row * 2)._datax_dev is None


def test_resident_burst_reuse_requires_intact_rows():
    dev = jnp.arange(12.0).reshape(4, 3)
    rows = [ResidentArray.wrap(np.asarray(dev[i]), dev, i) for i in range(4)]
    assert _resident_burst(rows, 4) is dev
    # pad mismatch
    assert _resident_burst(rows, 8) is None
    # non-contiguous (a filtered row) breaks the link
    assert _resident_burst([rows[0], rows[2]], 4) is None
    # a plain ndarray row breaks the link
    assert _resident_burst([rows[0], np.asarray(dev[1])], 4) is None


def test_to_device_batched_reuses_resident(jit_always):
    dev = jnp.arange(24.0).reshape(4, 2, 3)
    payloads = [{"x": ResidentArray.wrap(np.asarray(dev[i]), dev, i)}
                for i in range(4)]
    stats = {"resident_links": 0}
    out = _to_device_batched(payloads, 4, stats)
    assert out["x"] is dev
    assert stats["resident_links"] == 1


def test_linked_segments_pass_resident_rows_end_to_end(jit_always):
    upstream = _fused_process(resident=True)
    downstream = _fused_process()
    payloads = _payloads(8)
    mid = upstream.process_batch("s", payloads)
    assert all(isinstance(p["x"], ResidentArray) for p in mid)
    out = downstream.process_batch("s", mid)
    assert downstream.stats["resident_links"] == 1
    assert len(out) == 8
    # reuse is bit-identical to re-stacking from host
    plain = [{"x": np.array(p["x"])} for p in mid]
    again = _fused_process().process_batch("s", plain)
    assert all(np.array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
               for a, b in zip(out, again))


def test_unlinked_segments_emit_plain_arrays(jit_always):
    proc = _fused_process(resident=False)
    out = proc.process_batch("s", _payloads(4))
    assert not any(isinstance(p["x"], ResidentArray) for p in out)


# ---------------------------------------------------------------------------
# Burst autotune
# ---------------------------------------------------------------------------

def test_autotune_doubles_after_streak(jit_always):
    proc = _fused_process(max_batch=None)
    assert proc.current_max_batch() == fusion.DEFAULT_MAX_BATCH
    full = _payloads(fusion.DEFAULT_MAX_BATCH)
    for _ in range(AUTOTUNE_STREAK):
        proc.process_batch("s", full)
    assert proc.current_max_batch() == 2 * fusion.DEFAULT_MAX_BATCH
    assert proc.stats["max_batch_current"] == 2 * fusion.DEFAULT_MAX_BATCH


def test_autotune_resets_on_partial_burst(jit_always):
    proc = _fused_process(max_batch=None)
    full = _payloads(fusion.DEFAULT_MAX_BATCH)
    for _ in range(AUTOTUNE_STREAK - 1):
        proc.process_batch("s", full)
    proc.process_batch("s", _payloads(2))   # partial: mailbox drained
    for _ in range(AUTOTUNE_STREAK - 1):
        proc.process_batch("s", full)
    assert proc.current_max_batch() == fusion.DEFAULT_MAX_BATCH


def test_autotune_caps_at_max(jit_always):
    proc = _fused_process(max_batch=None)
    cap = fusion.AUTOTUNE_MAX_BATCH
    rounds = 0
    while proc.current_max_batch() < cap and rounds < 100:
        proc.process_batch("s", _payloads(proc.current_max_batch()))
        rounds += 1
    assert proc.current_max_batch() == cap
    for _ in range(2 * AUTOTUNE_STREAK):    # saturated: never exceeds the cap
        proc.process_batch("s", _payloads(cap))
    assert proc.current_max_batch() == cap


def test_autotune_halves_after_sustained_over_budget(jit_always, monkeypatch):
    # a zero budget makes every burst a latency breach: after
    # AUTOTUNE_DOWN_STREAK of them the ceiling halves, and it keeps
    # halving down to the floor of 1 — never below
    monkeypatch.setattr(fusion, "AUTOTUNE_BUDGET_S", 0.0)
    proc = _fused_process(max_batch=None)
    start = proc.current_max_batch()
    full = _payloads(start)
    for _ in range(fusion.AUTOTUNE_DOWN_STREAK):
        proc.process_batch("s", full)
    assert proc.current_max_batch() == start // 2
    assert proc.stats["max_batch_current"] == start // 2
    for _ in range(20 * fusion.AUTOTUNE_DOWN_STREAK):
        proc.process_batch("s", full)
    assert proc.current_max_batch() == 1


def test_autotune_isolated_slow_burst_does_not_shrink(jit_always,
                                                      monkeypatch):
    proc = _fused_process(max_batch=None)
    start = proc.current_max_batch()
    full = _payloads(start)
    # one over-budget burst, then healthy ones: the slow streak resets, so
    # the ceiling never shrinks (and the breach also reset the GROW streak)
    monkeypatch.setattr(fusion, "AUTOTUNE_BUDGET_S", 0.0)
    proc.process_batch("s", full)
    monkeypatch.setattr(fusion, "AUTOTUNE_BUDGET_S", 1e9)
    for _ in range(fusion.AUTOTUNE_DOWN_STREAK):
        proc.process_batch("s", full)
    assert proc.current_max_batch() >= start


def test_declared_max_batch_disables_autotune(jit_always):
    proc = _fused_process(max_batch=8)
    assert not hasattr(proc, "current_max_batch")
    assert proc.default_max_batch == 8
    for _ in range(2 * AUTOTUNE_STREAK):
        proc.process_batch("s", _payloads(8))
    assert proc.stats["max_batch_current"] == 8


# ---------------------------------------------------------------------------
# Metrics surface
# ---------------------------------------------------------------------------

def test_stats_carry_mesh_fields(jit_always):
    proc = _fused_process()
    for key in ("sharded_bursts", "resident_links", "mesh_devices",
                "max_batch_current"):
        assert key in proc.stats
    assert proc.stats["mesh_devices"] == (fusion.fusion_mesh().size
                                          if fusion.fusion_mesh() else 1)
