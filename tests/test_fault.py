"""Fault tolerance: preemption-save, stragglers, restart, elastic re-mesh."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.train.fault import ElasticController, StepTimeMonitor
from repro.train.trainer import Trainer, TrainerConfig

RUN = RunConfig(attention_impl="chunked", attention_chunk=32, remat="none")


@pytest.fixture
def workdir(tmp_path):
    return str(tmp_path / "run")


def _trainer(workdir, **kw):
    cfg = get_smoke_config("minitron-4b")
    tcfg = TrainerConfig(global_batch=4, seq_len=32, ckpt_every=2,
                         total_steps=50, workdir=workdir, **kw)
    return Trainer(cfg, RUN, tcfg)


def test_preemption_checkpoints_and_stops(workdir):
    tr = _trainer(workdir)
    tr.init_or_restore()
    tr.run_steps(3)
    tr.preemption.preempt()
    more = tr.run_steps(5)
    assert more == []                       # stopped immediately
    assert tr.ckpt.latest_step() == 3       # preemption checkpoint written
    tr.close()


def test_restart_resumes_from_checkpoint(workdir):
    tr = _trainer(workdir)
    tr.init_or_restore()
    tr.run_steps(4)
    tr.ckpt.wait()
    w_before = np.asarray(jax.tree.leaves(tr.params)[0], np.float32)
    tr.close()

    tr2 = _trainer(workdir)
    tr2.init_or_restore()
    assert tr2.step == 4                    # ckpt_every=2 -> saved at 4
    w_after = np.asarray(jax.tree.leaves(tr2.params)[0], np.float32)
    np.testing.assert_array_equal(w_before, w_after)
    m2 = tr2.run_steps(2)
    assert [m["step"] for m in m2] == [5, 6]
    tr2.close()


def test_straggler_monitor():
    mon = StepTimeMonitor(factor=2.0, warmup_steps=2)
    for i in range(6):
        assert not mon.record(i, 0.10)
    assert mon.record(6, 0.35)              # 3.5x EWMA -> straggler
    assert mon.straggler_steps[0][0] == 6
    # straggler did not poison the baseline
    assert abs(mon.ewma - 0.10) < 1e-6
    assert not mon.record(7, 0.11)


def test_elastic_remesh_restore(tmp_path):
    """Full elastic path: checkpoint -> 'lose' devices -> new mesh -> restore."""
    from repro.train.checkpoint import CheckpointManager
    cfg = get_smoke_config("minitron-4b")
    ec = ElasticController(cfg, RUN)
    from repro import models
    params = models.init(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, params, blocking=True)

    # surviving set = all local devices (1 on CPU); mesh rebuild + restore
    mesh = ec.build_mesh(jax.devices(), model_axis=1)
    shardings = ec.reshard_plan(jax.eval_shape(lambda: params), mesh)
    restored, manifest = mgr.restore(jax.eval_shape(lambda: params),
                                     shardings=shardings)
    assert manifest["step"] == 7
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(restored)[0], np.float32),
        np.asarray(jax.tree.leaves(params)[0], np.float32))
    assert any("mesh rebuilt" in e for e in ec.events)


def test_elastic_rejects_indivisible():
    cfg = get_smoke_config("minitron-4b")
    ec = ElasticController(cfg, RUN)
    with pytest.raises(ValueError):
        ec.build_mesh(jax.devices(), model_axis=7)
