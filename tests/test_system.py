"""End-to-end behaviour: the paper's fever-screening app (Fig. 3) rebuilt on
the platform, plus the SDK surface and whole-app validation."""
import time

import numpy as np
import pytest

from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        AppValidationError, ConfigSchema, DatabaseSpec,
                        DriverSpec, FieldSpec, GadgetSpec, Operator,
                        SensorSpec, StreamSchema, StreamSpec, drain,
                        sdk_entrypoint)


def _fever_app(results: list) -> Application:
    """Fig. 3 analog: thermal + RGB sensors, 5 AUs, DB, gate actuator."""
    frame = StreamSchema.of(frame_id=FieldSpec("int"),
                            data=FieldSpec("ndarray"))

    def camera_driver(ctx):
        rng = np.random.default_rng(ctx.config["seed"])

        def gen():
            for i in range(ctx.config["frames"]):
                if not ctx.running:
                    return
                yield {"frame_id": i,
                       "data": rng.random((8, 8)).astype(np.float32)}
        return gen()

    def detector(ctx):          # face detection analog
        return lambda s, p: {"frame_id": p["frame_id"],
                             "data": p["data"] * 0.5}

    def tracker(ctx):           # tracking analog (stateful)
        table = ctx.db.ensure_table("tracks") if ctx.db else None

        def process(s, p):
            if table is not None:
                table.put(p["frame_id"], {"seen": True})
            return {"frame_id": p["frame_id"], "data": p["data"]}
        return process

    def alignment(ctx):
        return lambda s, p: {"frame_id": p["frame_id"], "data": p["data"]}

    fused: dict[int, dict] = {}

    def fusion(ctx):            # thermal+visual fusion (2 input streams)
        def process(stream, p):
            other = fused.pop(p["frame_id"], None)
            if other is None:
                fused[p["frame_id"]] = p
                return None
            return {"frame_id": p["frame_id"],
                    "data": (p["data"] + other["data"]) / 2}
        return process

    def screening(ctx):
        thr = ctx.config["threshold"]

        def process(s, p):
            return {"frame_id": p["frame_id"],
                    "fever": bool(p["data"].mean() > thr)}
        return process

    def gate(ctx):              # entry-gate actuator
        def process(s, p):
            results.append((p["frame_id"], p["fever"]))
        return process

    app = Application(name="fever-screening")
    app.driver(DriverSpec(
        name="camera", logic=camera_driver,
        config_schema=ConfigSchema.of(seed=("int", 0), frames=("int", 20)),
        output_schema=frame))
    for name, logic in [("detector", detector), ("tracker", tracker),
                        ("alignment", alignment), ("fusion", fusion)]:
        app.analytics_unit(AnalyticsUnitSpec(
            name=name, logic=logic, output_schema=frame,
            stateful=(name == "tracker")))
    app.analytics_unit(AnalyticsUnitSpec(
        name="screening", logic=screening,
        config_schema=ConfigSchema.of(threshold=("float", 0.25)),
        output_schema=StreamSchema.of(frame_id=FieldSpec("int"),
                                      fever=FieldSpec("bool"))))
    app.actuator(ActuatorSpec(name="gate", logic=gate))
    app.database(DatabaseSpec(name="tracks-db"))
    app.sensor(SensorSpec(name="thermal", driver="camera",
                          config={"seed": 1, "frames": 20}))
    app.sensor(SensorSpec(name="rgb", driver="camera",
                          config={"seed": 2, "frames": 20}))
    app.stream(StreamSpec(name="detections", analytics_unit="detector",
                          inputs=("rgb",)))
    app.stream(StreamSpec(name="tracks", analytics_unit="tracker",
                          inputs=("detections",), fixed_instances=1))
    app.stream(StreamSpec(name="aligned-thermal", analytics_unit="alignment",
                          inputs=("thermal",)))
    app.stream(StreamSpec(name="fused", analytics_unit="fusion",
                          inputs=("tracks", "aligned-thermal"),
                          fixed_instances=1))
    app.stream(StreamSpec(name="screenings", analytics_unit="screening",
                          inputs=("fused",), config={"threshold": 0.375}))
    app.gadget(GadgetSpec(name="entry-gate", actuator="gate",
                          inputs=("screenings",)))
    return app


def test_fever_screening_pipeline_end_to_end():
    """The paper's flagship application: 2 sensors, 5 AUs, 1 DB, 1 actuator,
    1 gadget — zero user communication code."""
    results: list = []
    op = Operator(reconcile_interval_s=0.1)
    app = _fever_app(results)
    assert app.loc_footprint() == 16
    app.deploy(op)
    op.start()
    deadline = time.monotonic() + 30
    while len(results) < 20 and time.monotonic() < deadline:
        time.sleep(0.05)
    assert len(results) >= 20
    assert {fid for fid, _ in results} == set(range(20))
    assert all(isinstance(f, bool) for _, f in results)
    # platform-installed stateful AU database exists and has content
    assert op.store.exists("au-tracks")
    assert len(op.store.get("au-tracks").table("tracks")) > 0
    op.shutdown()


def test_app_validation_catches_dangling_and_cycles():
    app = Application(name="bad")
    app.analytics_unit(AnalyticsUnitSpec(name="a", logic=lambda c: None))
    app.stream(StreamSpec(name="x", analytics_unit="a", inputs=("y",)))
    app.stream(StreamSpec(name="y", analytics_unit="a", inputs=("x",)))
    with pytest.raises(AppValidationError):
        app.validate()


def test_sdk_style_entrypoint():
    """The paper's SDK: get_configuration / next / emit."""
    op = Operator(reconcile_interval_s=0.1)

    def src(ctx):
        def gen():
            for i in range(5):
                yield {"value": i}
        return gen()

    @sdk_entrypoint
    def au_main(dx):
        cfg = dx.get_configuration()
        assert cfg["offset"] == 7
        while dx.running:
            item = dx.next(timeout=0.2)
            if item is None:
                continue
            stream, msg = item
            dx.emit({"value": msg["value"] + cfg["offset"]})

    schema = StreamSchema.of(value=FieldSpec("int"))
    op.register_driver(DriverSpec(name="src", logic=src,
                                  output_schema=schema))
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="sdk-au", logic=au_main,
        config_schema=ConfigSchema.of(offset=("int", 7)),
        output_schema=schema))
    op.register_sensor(SensorSpec(name="in", driver="src"), start=False)
    op.create_stream(StreamSpec(name="out", analytics_unit="sdk-au",
                                inputs=("in",)))
    sub = op.subscribe("out")
    op.start_pending_sensors()
    vals = sorted(m.payload["value"] for m in drain(sub, 5))
    assert vals == [7, 8, 9, 10, 11]
    op.shutdown()
