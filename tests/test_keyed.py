"""Keyed delivery (tentpole PR 4): hash-partitioned streams + per-key state.

Bus level: ``subscribe(..., group=..., key=...)`` pins every key to one
healthy member via a stable partition ring (rendezvous hashing); a departing
member's partitions — and its queued backlog — re-home to survivors whole
and in order.

Platform level: ``StreamSpec(delivery="keyed", key=...)`` plumbs the policy
through operator/executor/sidecar; the DSL grows ``.key_by`` and per-key
stateful combinators (``.reduce``, ``.window(per_key=True)``) whose state
lives in the stream's shared platform database (``KeyedStore``), so
``.scaled()`` pools survive partition rebalances without losing state; the
autoscaler reads per-partition backlog; fused units inherit the entry
stream's key policy and barrier on mid-chain keyed consumers.
"""
import time

import pytest

from repro.core import (AnalyticsUnitSpec, App, AutoScaler, CoherenceError,
                        ConfigSchema, DriverSpec, DSLError, FieldSpec,
                        KeyedStore, MessageBus, Operator, OperatorError,
                        ScalePolicy, SensorSpec, StreamSchema, StreamSpec,
                        connect, drain, partition_of, ring_assignment)
from repro.core.bus import KEYED_PARTITIONS, BusError

KV = StreamSchema.of(k=FieldSpec("str"), v=FieldSpec("int"))


def _drain_now(sub):
    out = []
    while True:
        m = sub.next(timeout=0)
        if m is None:
            return out
        out.append(m.payload)


# ---------------------------------------------------------------------------
# Bus-level semantics
# ---------------------------------------------------------------------------

@pytest.fixture
def bus():
    b = MessageBus()
    b.register_subject("s", KV)
    return b


def test_same_key_same_member_in_order(bus):
    tok = bus.issue_token("t", ["s"])
    members = [bus.subscribe("s", token=tok, group="pool", key="k",
                             name=f"m{i}") for i in range(3)]
    keys = [f"key-{i}" for i in range(12)]
    for v in range(5):
        for k in keys:
            bus.publish("s", {"k": k, "v": v}, token=tok)
    owner: dict[str, str] = {}
    seen: dict[str, list[int]] = {}
    for m in members:
        for p in _drain_now(m):
            assert owner.setdefault(p["k"], m.name) == m.name, \
                f"key {p['k']} split across members"
            seen.setdefault(p["k"], []).append(p["v"])
    assert sorted(seen) == sorted(keys)          # every key delivered
    assert all(vals == [0, 1, 2, 3, 4] for vals in seen.values())


def test_keyed_group_stats_surface_ring(bus):
    tok = bus.issue_token("t", ["s"])
    bus.subscribe("s", token=tok, group="pool", key="k", name="a")
    bus.subscribe("s", token=tok, group="pool", key="k", name="b")
    for i in range(6):
        bus.publish("s", {"k": f"x{i}", "v": i}, token=tok)
    g = bus.stats()["s"]["groups"]["pool"]
    assert g["policy"] == "keyed" and g["key"] == "k"
    assert g["delivered"] == 6
    assert len(g["assignment"]) == KEYED_PARTITIONS
    assert set(g["assignment"].values()) <= {"a", "b"}
    # exact per-partition backlog: 6 queued messages across partitions
    assert sum(g["partition_backlog"].values()) == 6
    # ...and it drains to zero as members consume
    for sub in list(bus._subs["s"]):
        _drain_now(sub)
    assert bus.stats()["s"]["groups"]["pool"]["partition_backlog"] == {}


def test_departing_member_partitions_rehome_in_order(bus):
    """Scale-down: the leaver's queued backlog re-homes per partition (to
    the rendezvous runner-up), ordered BEFORE any newer message for those
    keys; surviving members' keys are untouched."""
    tok = bus.issue_token("t", ["s"])
    a = bus.subscribe("s", token=tok, group="pool", key="k", name="a")
    b = bus.subscribe("s", token=tok, group="pool", key="k", name="b")
    keys = [f"key-{i}" for i in range(10)]
    for v in range(3):
        for k in keys:
            bus.publish("s", {"k": k, "v": v}, token=tok)
    assert a.qsize() and b.qsize()          # both members own some keys
    bus.unsubscribe(a)
    for v in range(3, 5):
        for k in keys:
            bus.publish("s", {"k": k, "v": v}, token=tok)
    seen: dict[str, list[int]] = {}
    for p in _drain_now(b):
        seen.setdefault(p["k"], []).append(p["v"])
    assert sorted(seen) == sorted(keys)
    for k, vals in seen.items():
        assert vals == [0, 1, 2, 3, 4], (k, vals)   # in order, none lost
    assert bus.stats()["s"]["groups"]["pool"]["rerouted"] > 0


def test_keyed_wire_members_roundtrip(bus):
    tok = bus.issue_token("t", ["s"])
    w = bus.subscribe("s", token=tok, group="pool", key="k", name="w",
                      wire=True)
    bus.publish("s", {"k": "x", "v": 1}, token=tok)
    msg = w.next(timeout=1)
    assert msg.payload == {"k": "x", "v": 1}


def test_keyed_policy_mismatch_rejected(bus):
    tok = bus.issue_token("t", ["s"])
    bus.subscribe("s", token=tok, group="pool", key="k", name="a")
    with pytest.raises(BusError):
        bus.subscribe("s", token=tok, group="pool", name="b")      # no key
    with pytest.raises(BusError):
        bus.subscribe("s", token=tok, group="pool", key="v", name="c")
    with pytest.raises(BusError):
        bus.subscribe("s", token=tok, group="pool", key="k", name="d",
                      partitions=16)         # ring size fixed at creation
    with pytest.raises(BusError):
        # duplicate member name would collapse both onto one ring identity
        bus.subscribe("s", token=tok, group="pool", key="k", name="a")
    with pytest.raises(BusError):
        bus.subscribe("s", token=tok, group="p2", key="k", partitions=0)
    bus2 = MessageBus()
    bus2.register_subject("s", KV)
    tok2 = bus2.issue_token("t", ["s"])
    bus2.subscribe("s", token=tok2, group="g", name="plain")
    with pytest.raises(BusError):
        bus2.subscribe("s", token=tok2, group="g", key="k", name="keyed")
    with pytest.raises(BusError):
        bus2.subscribe("s", token=tok2, key="k", name="keyed-ungrouped")


def test_missing_key_field_routes_deterministically(bus):
    """Payloads without the key field all hash the same (key None) — they
    stay single-member and ordered rather than being scattered."""
    bus_ = MessageBus()
    bus_.register_subject("u")            # untyped subject
    tok = bus_.issue_token("t", ["u"])
    members = [bus_.subscribe("u", token=tok, group="pool", key="k",
                              name=f"m{i}") for i in range(3)]
    for i in range(6):
        bus_.publish("u", {"v": i}, token=tok)
    got = [len(_drain_now(m)) for m in members]
    assert sorted(got) == [0, 0, 6]


# ---------------------------------------------------------------------------
# The partition ring: stability + minimal disruption (hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except Exception:  # pragma: no cover - minimal-deps CI leg
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    _members = st.lists(st.text("abcdefgh0123-", min_size=1, max_size=12),
                        unique=True, min_size=1, max_size=8)

    @settings(max_examples=60, deadline=None)
    @given(_members, st.sampled_from([8, 32, 64]), st.data())
    def test_ring_stable_and_minimally_disruptive(members, nparts, data):
        """Same membership -> identical assignment (same key, same member);
        a single leave moves exactly the leaver's partitions (each to its
        runner-up); a single join moves exactly the partitions the joiner
        wins.  No unrelated partition ever moves."""
        before = ring_assignment(members, nparts)
        assert before == ring_assignment(list(members), nparts)  # stable

        leaver = data.draw(st.sampled_from(members), label="leaver")
        survivors = [m for m in members if m != leaver]
        if survivors:
            after = ring_assignment(survivors, nparts)
            moved = {p for p in range(nparts) if after[p] != before[p]}
            owned = {p for p, o in before.items() if o == leaver}
            assert moved == owned                 # == |leaver's partitions|

        joiner = data.draw(st.text("xyz987", min_size=1, max_size=12)
                           .filter(lambda s: s not in members),
                           label="joiner")
        grown = ring_assignment(members + [joiner], nparts)
        moved = {p for p in range(nparts) if grown[p] != before[p]}
        assert all(grown[p] == joiner for p in moved)
        assert moved == {p for p, o in grown.items() if o == joiner}

    @settings(max_examples=40, deadline=None)
    @given(st.one_of(st.text(max_size=20), st.integers(), st.binary(max_size=16),
                     st.none()),
           st.sampled_from([8, 64]))
    def test_partition_of_is_stable_and_in_range(key, nparts):
        p = partition_of(key, nparts)
        assert 0 <= p < nparts
        assert p == partition_of(key, nparts)


# ---------------------------------------------------------------------------
# Operator level
# ---------------------------------------------------------------------------

def kv_driver(ctx):
    def gen():
        for v in range(int(ctx.config.get("rounds", 5))):
            for i in range(int(ctx.config.get("keys", 6))):
                if not ctx.running:
                    return
                yield {"k": f"key-{i}", "v": v}
    return gen()


def counting_au(ctx):
    """Per-key counter whose state lives in the platform database."""
    store = KeyedStore(ctx.db, "counts")

    def process(stream, payload):
        n = store.get(payload["k"], 0) + 1
        store.put(payload["k"], n)
        return {"k": payload["k"], "v": n}
    return process


def _operator() -> Operator:
    op = Operator(reconcile_interval_s=0.05)
    op.register_driver(DriverSpec(
        name="kv", logic=kv_driver,
        config_schema=ConfigSchema.of(rounds=("int", 5), keys=("int", 6)),
        output_schema=KV))
    return op


def test_keyed_stream_spec_validation():
    op = _operator()
    try:
        op.register_analytics_unit(AnalyticsUnitSpec(
            name="count", logic=counting_au, output_schema=KV,
            stateful=True))
        op.register_sensor(SensorSpec(name="events", driver="kv"))
        with pytest.raises(OperatorError):
            op.create_stream(StreamSpec(name="c1", analytics_unit="count",
                                        inputs=("events",), delivery="keyed"))
        with pytest.raises(OperatorError):
            op.create_stream(StreamSpec(name="c2", analytics_unit="count",
                                        inputs=("events",), key="k"))
        with pytest.raises(CoherenceError):
            op.create_stream(StreamSpec(name="c3", analytics_unit="count",
                                        inputs=("events",), delivery="keyed",
                                        key="nope"))
        op.create_stream(StreamSpec(name="c4", analytics_unit="count",
                                    inputs=("events",), delivery="keyed",
                                    key="k"))
    finally:
        op.shutdown()


def test_keyed_stateful_pool_scale_down_keeps_state():
    """4 keyed instances count per key; stopping one mid-run re-homes its
    partitions to survivors that read the same store — every key's final
    count is exact and every emission is in per-key order."""
    rounds, keys = 8, 8
    op = _operator()
    try:
        op.register_analytics_unit(AnalyticsUnitSpec(
            name="count", logic=counting_au, output_schema=KV,
            stateful=True, max_instances=8))
        op.register_sensor(SensorSpec(name="events", driver="kv",
                                      config={"rounds": rounds,
                                              "keys": keys}), start=False)
        op.create_stream(StreamSpec(name="counts", analytics_unit="count",
                                    inputs=("events",), fixed_instances=4,
                                    delivery="keyed", key="k"))
        handles = op.executor.instances_of("counts")
        assert len(handles) == 4
        assert all(h.sidecar.key == "k" for h in handles)
        sub = op.subscribe("counts")
        op.start_pending_sensors()
        time.sleep(0.05)
        op.executor.stop_instance(handles[0].instance_id)   # forced leave
        msgs = drain(sub, rounds * keys, timeout=20)
        per_key: dict[str, list[int]] = {}
        for m in msgs:
            per_key.setdefault(m.payload["k"], []).append(m.payload["v"])
        for k, vals in per_key.items():
            assert vals == list(range(1, rounds + 1)), (k, vals)
        table = op.store.get("au-counts").table("counts")
        for i in range(keys):
            assert table.get(f"key-{i}")["value"] == rounds
    finally:
        op.shutdown()


def test_sidecar_metrics_surface_lag_and_assignment():
    op = _operator()
    try:
        op.register_analytics_unit(AnalyticsUnitSpec(
            name="count", logic=counting_au, output_schema=KV,
            stateful=True))
        op.register_sensor(SensorSpec(name="events", driver="kv"), start=False)
        op.create_stream(StreamSpec(name="counts", analytics_unit="count",
                                    inputs=("events",), fixed_instances=2,
                                    delivery="keyed", key="k"))
        h = op.executor.instances_of("counts")[0]
        m = h.sidecar.metrics()
        assert m["key"] == "k"
        info = m["groups"]["events"]
        assert info["policy"] == "keyed" and info["key"] == "k"
        assert info["members"] == 2
        assert set(info["assignment"].values()) <= \
            {x.sidecar._subs[0].name for x in
             op.executor.instances_of("counts")}
        assert "lag" in info and "partition_backlog" in info
    finally:
        op.shutdown()


# ---------------------------------------------------------------------------
# DSL level
# ---------------------------------------------------------------------------

def _kv_app():
    app = App("keyed-dsl")

    @app.driver(emits=KV)
    def src(ctx, rounds=5, keys=6):
        return ({"k": f"key-{i}", "v": v}
                for v in range(rounds) for i in range(keys))
    return app, app.sense("events", src)


def test_key_by_validates_field():
    _, events = _kv_app()
    with pytest.raises(DSLError):
        events.key_by("nope")
    assert events.key_by("k").key == "k"
    assert events.key is None            # handles are immutable descriptors


def test_reduce_requires_key_by():
    _, events = _kv_app()
    with pytest.raises(DSLError):
        events.reduce(lambda acc, p: acc)
    with pytest.raises(DSLError):
        events.window(3, per_key=True)


def test_keyed_combinators_compile_to_keyed_specs():
    app, events = _kv_app()
    counts = events.key_by("k").reduce(lambda a, p: (a or 0) + 1,
                                       name="counts").scaled(instances=3)
    spec = next(s for s in app._streams if s.name == "counts")
    assert spec.delivery == "keyed" and spec.key == "k"
    assert spec.fixed_instances == 3
    assert app._aus[spec.analytics_unit].stateful
    assert counts.key == "k"             # reduce emits the key field


def test_scaled_guards_on_keyed_streams():
    app, events = _kv_app()
    win = events.key_by("k").window(3, per_key=True, name="w")
    win.scaled(instances=2)              # keyed stateful stage CAN scale now
    spec = next(s for s in app._streams if s.name == "w")
    assert spec.fixed_instances == 2 and spec.delivery == "keyed"
    with pytest.raises(DSLError):
        win.scaled(delivery="broadcast")     # would discard the key policy
    with pytest.raises(DSLError):
        win.scaled(delivery="group")
    # unkeyed stateful combinators remain pinned
    unkeyed = events.window(3, name="w2")
    with pytest.raises(DSLError):
        unkeyed.scaled(instances=2)


def test_keyed_map_propagates_and_typed_schema_breaks_chain():
    app, events = _kv_app()
    keyed = events.key_by("k")
    kept = keyed.map(lambda p: p, name="m1")             # untyped out
    assert kept.key == "k"
    NO_K = StreamSchema.of(v=FieldSpec("int"))
    dropped = keyed.map(lambda p: {"v": p["v"]}, emits=NO_K, name="m2")
    assert dropped.key is None
    spec = next(s for s in app._streams if s.name == "m2")
    assert spec.delivery == "keyed"      # the stage itself still keyed


def test_keyed_window_per_key_flow():
    app, events = _kv_app()
    (events.key_by("k").window(2, per_key=True, name="pairs")
        .scaled(instances=2))
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        sub = op.subscribe("pairs", maxsize=64)
        op.start_pending_sensors()
        msgs = drain(sub, 12, timeout=10)    # 6 keys x 5 rounds -> 2 windows
        for m in msgs:
            w = m.payload["window"]
            assert len(w) == 2 and len({x["k"] for x in w}) == 1
            assert [x["v"] for x in w] in ([0, 1], [2, 3])
        assert sub.next(timeout=0.2) is None  # round 4 stays buffered


def test_keyed_fused_entry_inherits_key_policy():
    app = App("keyed-fused")

    @app.driver(emits=KV)
    def src(ctx, n=5):
        return ({"k": f"key-{i}", "v": i} for i in range(n))

    (app.sense("raw", src)
        .key_by("k")
        .map(lambda p: {"k": p["k"], "v": p["v"] + 1}, emits=KV,
             device=True, name="a")
        .map(lambda p: {"k": p["k"], "v": p["v"] * 2}, emits=KV,
             device=True, name="b"))
    built = app.build()
    fused = [s for s in built.streams if s.name == "b"]
    assert len(fused) == 1
    assert fused[0].delivery == "keyed" and fused[0].key == "k"
    assert any(a.fused_stages for a in built.analytics_units)


def test_mid_chain_keyed_consumer_is_fusion_barrier():
    app = App("keyed-barrier")

    @app.driver(emits=KV)
    def src(ctx, n=5):
        return ({"k": f"key-{i}", "v": i} for i in range(n))

    stage_a = app.sense("raw", src).map(
        lambda p: {"k": p["k"], "v": p["v"] + 1}, emits=KV, device=True,
        name="a")
    # re-partition point: the keyed consumer's input must stay on the bus
    stage_a.key_by("k").map(lambda p: {"k": p["k"], "v": p["v"] * 2},
                            emits=KV, device=True, name="b")
    built = app.build()
    assert not any(a.fused_stages for a in built.analytics_units)
    spec_b = next(s for s in built.streams if s.name == "b")
    assert spec_b.delivery == "keyed" and spec_b.inputs == ("a",)


# ---------------------------------------------------------------------------
# Autoscaler: per-partition backlog is a scale-up signal
# ---------------------------------------------------------------------------

class _FakeKeyedSidecar:
    def __init__(self, backlog, partition_backlog, key="k"):
        self._m = {"instance": f"fake-{id(self):x}", "backlog": backlog,
                   "idle_s": 0.0, "dropped": 0, "key": key,
                   "groups": {"in": {"policy": "keyed",
                                     "partition_backlog": partition_backlog}}}

    def metrics(self):
        return dict(self._m, received=0, published=0, processed=0,
                    errors=0, latency_ewma_s=0, uptime_s=1)


class _H:
    def __init__(self, backlog, partition_backlog, key="k"):
        self.sidecar = _FakeKeyedSidecar(backlog, partition_backlog, key)


def test_autoscaler_scales_up_on_hot_partition():
    scaler = AutoScaler(ScalePolicy(backlog_high=10, backlog_low=1,
                                    idle_s=0.0, cooldown_s=0.0))
    # aggregate is comfortable (12 < 2x10) but one partition holds 11
    # queued messages: a hot key pinned to one member -> scale up
    hot = _H(11, {3: 11})
    cold = _H(1, {})
    assert scaler.decide("s", [hot, cold], 1, 8) == 4
    # same shape unkeyed (no key field): aggregate rule only -> steady
    plain_hot = _H(11, {3: 11}, key=None)
    assert scaler.decide("t", [plain_hot, _H(1, {}, key=None)], 1, 8) == 2


def test_keyed_store_shared_across_instances(tmp_path):
    from repro.core import Database
    db = Database("shared")
    a = KeyedStore(db, "counts")
    b = KeyedStore(db, "counts")        # second instance, same platform db
    a.put("k1", 41)
    assert b.get("k1") == 41            # rebalanced partition finds state
    b.put("k1", b.get("k1") + 1)
    assert a.get("k1") == 42
    assert len(a) == 1 and a.keys() == ["k1"]
    a.delete("k1")
    assert b.get("k1", 0) == 0
    solo = KeyedStore(None, "local")    # db-less fallback for bare factories
    solo.put("x", 1)
    assert solo.get("x") == 1
