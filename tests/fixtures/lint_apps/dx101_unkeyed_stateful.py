"""DX101: a per-key stateful ``reduce`` stage running under plain group
delivery — its KeyedStore folds are only exactly-once when every key
sticks to one instance, which needs keyed delivery."""
from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, GadgetSpec, SensorSpec, StreamSpec)

from _common import folder, gen_factory, sink

EXPECT = "DX101"


def build_app() -> Application:
    return Application(
        name="dx101",
        drivers=[DriverSpec(name="src", logic=gen_factory)],
        analytics_units=[AnalyticsUnitSpec(
            name="running-total", logic=folder,
            stateful=True, combinator="reduce")],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        sensors=[SensorSpec(name="events", driver="src")],
        streams=[StreamSpec(name="totals", analytics_unit="running-total",
                            inputs=("events",), delivery="group")],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("totals",))],
    )
