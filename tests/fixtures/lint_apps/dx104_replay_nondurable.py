"""DX104: ``replay_from=`` on a stream whose input subject is never marked
durable — there is no log to replay, so the stream would start empty."""
from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, GadgetSpec, SensorSpec, StreamSpec)

from _common import gen_factory, passthrough, sink

EXPECT = "DX104"


def build_app() -> Application:
    return Application(
        name="dx104",
        drivers=[DriverSpec(name="src", logic=gen_factory)],
        analytics_units=[AnalyticsUnitSpec(name="audit", logic=passthrough)],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        sensors=[SensorSpec(name="events", driver="src")],  # NOT durable
        streams=[StreamSpec(name="audited", analytics_unit="audit",
                            inputs=("events",), replay_from="earliest")],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("audited",))],
    )
