"""DX303: two stages of one fusible DEVICE chain declare different
``max_batch`` values — fusion folds them onto one unit and the stage
closest to the segment exit silently wins."""
from repro.core import App

EXPECT = "DX303"


def build_app() -> App:
    app = App("dx303")

    def double(p):
        return {"x": p["x"] * 2}

    def halve(p):
        return {"x": p["x"] / 2}

    def src(ctx, n=4):
        def g():
            for i in range(n):
                yield {"x": float(i)}
        return g()

    app.driver(src, name="src")
    chain = app.sense("numbers", "src").map(double, name="doubled",
                                            device=True)
    chain.scaled(max_batch=32)   # upstream asks for deep bursts...
    tail = chain.map(halve, name="halved", device=True)
    tail.scaled(max_batch=1)     # ...downstream forces per-message dispatch
    tail.tap()
    return app
