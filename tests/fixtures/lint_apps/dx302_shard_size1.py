"""DX302: a mesh axis named on a size-1 dimension — no mesh larger than 1
can ever divide it, so the hint silently degrades to replication."""
from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, GadgetSpec, SensorSpec, ShardSpec,
                        StreamSchema, StreamSpec)

from _common import gen_factory, passthrough, sink

EXPECT = "DX302"

# leading dim has extent 1 but names the "data" axis
FRAMES = StreamSchema.device(x=((1, 16), "float32",
                                ShardSpec(("data", None))))


def build_app() -> Application:
    return Application(
        name="dx302",
        drivers=[DriverSpec(name="src", logic=gen_factory,
                            output_schema=FRAMES)],
        analytics_units=[AnalyticsUnitSpec(
            name="pass", logic=passthrough, input_schemas=(FRAMES,))],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        sensors=[SensorSpec(name="frames", driver="src")],
        streams=[StreamSpec(name="passed", analytics_unit="pass",
                            inputs=("frames",))],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("passed",))],
    )
