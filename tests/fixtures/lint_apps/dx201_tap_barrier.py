"""DX201 (info): an adjacent DEVICE->DEVICE chain that does NOT fuse —
the interior stream is ``.tap()``-promised, which is a fusion barrier the
analyzer names explicitly (``TAPPED``)."""
from repro.core import App

EXPECT = "DX201"


def build_app() -> App:
    app = App("dx201")

    def double(p):
        return {"x": p["x"] * 2}

    def halve(p):
        return {"x": p["x"] / 2}

    def src(ctx, n=4):
        def g():
            for i in range(n):
                yield {"x": float(i)}
        return g()

    app.driver(src, name="src")
    stage1 = app.sense("numbers", "src").map(double, name="doubled",
                                             device=True)
    stage1.tap()  # the promise that splits the device chain
    stage1.map(halve, name="halved", device=True).tap()
    return app
