"""DX301: a ShardSpec whose rank does not match its field's shape — the
hint can never address the array, so sharded execution silently degrades."""
from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, GadgetSpec, SensorSpec, ShardSpec,
                        StreamSchema, StreamSpec)

from _common import gen_factory, passthrough, sink

EXPECT = "DX301"

# 2-D field, 1-entry hint: rank mismatch
FRAMES = StreamSchema.device(x=((8, 8), "float32", ShardSpec(("data",))))


def build_app() -> Application:
    return Application(
        name="dx301",
        drivers=[DriverSpec(name="src", logic=gen_factory,
                            output_schema=FRAMES)],
        analytics_units=[AnalyticsUnitSpec(
            name="pass", logic=passthrough, input_schemas=(FRAMES,))],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        sensors=[SensorSpec(name="frames", driver="src")],
        streams=[StreamSpec(name="passed", analytics_unit="pass",
                            inputs=("frames",))],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("passed",))],
    )
