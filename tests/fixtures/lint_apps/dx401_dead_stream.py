"""DX401: a stream nothing consumes — no downstream stream or gadget, no
``.tap()`` promise, no durable log.  Every message is dropped on the
floor."""
from repro.core import App

EXPECT = "DX401"


def build_app() -> App:
    app = App("dx401")

    def src(ctx, n=4):
        def g():
            for i in range(n):
                yield {"x": float(i)}
        return g()

    app.driver(src, name="src")
    app.sense("numbers", "src").map(lambda p: p, name="orphan")
    return app
