"""DX102: broadcast delivery into a stateful pool that can scale past one
instance — all instances share the stream's platform database, so every
update is applied once per instance (state double-counting)."""
from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, GadgetSpec, SensorSpec, StreamSpec)

from _common import folder, gen_factory, sink

EXPECT = "DX102"


def build_app() -> Application:
    return Application(
        name="dx102",
        drivers=[DriverSpec(name="src", logic=gen_factory)],
        analytics_units=[AnalyticsUnitSpec(
            name="counter", logic=folder, stateful=True, max_instances=4)],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        sensors=[SensorSpec(name="events", driver="src")],
        streams=[StreamSpec(name="counts", analytics_unit="counter",
                            inputs=("events",), delivery="broadcast")],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("counts",))],
    )
