"""DX404 (info): a producer schema field no typed consumer ever reads —
serialized, published, and dropped on every message."""
from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, FieldSpec, GadgetSpec, SensorSpec,
                        StreamSchema, StreamSpec)

from _common import gen_factory, passthrough, sink

EXPECT = "DX404"

FULL = StreamSchema.of(value=FieldSpec("float"), debug_blob=FieldSpec("str"))
SLIM = StreamSchema.of(value=FieldSpec("float"))


def build_app() -> Application:
    return Application(
        name="dx404",
        drivers=[DriverSpec(name="src", logic=gen_factory,
                            output_schema=FULL)],
        # the only consumer declares SLIM: "debug_blob" is never read
        analytics_units=[AnalyticsUnitSpec(
            name="pass", logic=passthrough, input_schemas=(SLIM,))],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        sensors=[SensorSpec(name="readings", driver="src")],
        streams=[StreamSpec(name="passed", analytics_unit="pass",
                            inputs=("readings",))],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("passed",))],
    )
