"""Shared stand-in logic factories for the lint-app fixture corpus.

Each fixture module plants exactly ONE hazard and declares it in its
``EXPECT`` attribute; ``tests/test_analyze.py`` asserts the analyzer fires
that code and nothing else.  The logic bodies here never run — the fixtures
are only ever *analyzed*, not deployed.
"""


def gen_factory(ctx):
    """Driver logic: a one-shot generator (never actually pulled)."""
    def g():
        yield {"x": 1}
    return g()


def passthrough(ctx):
    """AU logic: identity transform."""
    return lambda stream, payload: payload


def folder(ctx):
    """AU logic for stateful reduce-style stages."""
    return lambda stream, payload: payload


def sink(ctx):
    """Actuator logic: swallow every insight."""
    return lambda stream, payload: None
