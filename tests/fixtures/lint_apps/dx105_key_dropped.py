"""DX105: a keyed stream whose key field is dropped by the upstream
producer's schema — every message would hash on a missing field."""
from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, FieldSpec, GadgetSpec, SensorSpec,
                        StreamSchema, StreamSpec)

from _common import gen_factory, passthrough, sink

EXPECT = "DX105"

READING = StreamSchema.of(value=FieldSpec("float"))


def build_app() -> Application:
    return Application(
        name="dx105",
        drivers=[DriverSpec(name="src", logic=gen_factory,
                            output_schema=READING)],
        analytics_units=[AnalyticsUnitSpec(
            name="by-region", logic=passthrough,
            input_schemas=(READING,))],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        sensors=[SensorSpec(name="readings", driver="src")],
        # keyed on "region", but the producer only emits {"value"}
        streams=[StreamSpec(name="regional", analytics_unit="by-region",
                            inputs=("readings",), delivery="keyed",
                            key="region")],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("regional",))],
    )
