"""DX103: ``steal=True`` on a plain-group stream feeding a keyed consumer —
group stealing moves individual messages between members, perturbing the
publish order the downstream keyed stage depends on."""
from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, GadgetSpec, SensorSpec, StreamSpec)

from _common import gen_factory, passthrough, sink

EXPECT = "DX103"


def build_app() -> Application:
    return Application(
        name="dx103",
        drivers=[DriverSpec(name="src", logic=gen_factory)],
        analytics_units=[
            AnalyticsUnitSpec(name="normalize", logic=passthrough),
            AnalyticsUnitSpec(name="route", logic=passthrough)],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        sensors=[SensorSpec(name="events", driver="src")],
        streams=[
            StreamSpec(name="normalized", analytics_unit="normalize",
                       inputs=("events",), delivery="group", steal=True),
            StreamSpec(name="routed", analytics_unit="route",
                       inputs=("normalized",), delivery="keyed", key="x")],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("routed",))],
    )
