"""DX403: retention knobs on a subject that is not durable — there is no
log for the retention policy to bound, so the knobs silently do nothing."""
from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, GadgetSpec, SensorSpec, StreamSpec)

from _common import gen_factory, passthrough, sink

EXPECT = "DX403"


def build_app() -> Application:
    return Application(
        name="dx403",
        drivers=[DriverSpec(name="src", logic=gen_factory)],
        analytics_units=[AnalyticsUnitSpec(name="pass", logic=passthrough)],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        # retention without durable=True: nothing is ever retained
        sensors=[SensorSpec(name="events", driver="src",
                            retention={"max_records": 128})],
        streams=[StreamSpec(name="passed", analytics_unit="pass",
                            inputs=("events",))],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("passed",))],
    )
