"""DX402: a sharding hint spelled as a legacy bare tuple instead of a
:class:`~repro.core.ShardSpec` — deprecated since the typed addressing API
landed; the analyzer flags the call site statically."""
import warnings

from repro.core import (ActuatorSpec, AnalyticsUnitSpec, Application,
                        DriverSpec, FieldSpec, GadgetSpec, SensorSpec,
                        StreamSchema, StreamSpec)

from _common import gen_factory, passthrough, sink

EXPECT = "DX402"

with warnings.catch_warnings():
    # the legacy spelling warns at build time too — the fixture is about
    # the STATIC diagnostic, so keep the runtime warning out of test logs
    warnings.simplefilter("ignore", DeprecationWarning)
    FRAMES = StreamSchema.of(x=FieldSpec("device", shape=(8, 16),
                                         dtype="float32",
                                         sharding=("data", None)))


def build_app() -> Application:
    return Application(
        name="dx402",
        drivers=[DriverSpec(name="src", logic=gen_factory,
                            output_schema=FRAMES)],
        analytics_units=[AnalyticsUnitSpec(
            name="pass", logic=passthrough, input_schemas=(FRAMES,))],
        actuators=[ActuatorSpec(name="sink", logic=sink)],
        sensors=[SensorSpec(name="frames", driver="src")],
        streams=[StreamSpec(name="passed", analytics_unit="pass",
                            inputs=("frames",))],
        gadgets=[GadgetSpec(name="display", actuator="sink",
                            inputs=("passed",))],
    )
