"""Wire fast path (PR 9): coalesced frames, codec negotiation, stealing.

Frame-codec round-trips pin the v2 envelope (``hello``/``msgs``/``pubs``/
``dict``) on the zlib leg — the encoding every peer can read — with the
zstd + dictionary (``DXZ2``) leg skip-guarded on ``zstandard`` being
installed.  Negotiation tests cover the full matrix the ISSUE names: a v2
client against a v2 server, a zlib-only client negotiating DOWN, a raw
v1-framing socket that never says hello, and a ``proto=1`` hello.  The
liveness half regression-tests ``resubscribe=True`` across a reconnect
storm, and the stealing half drives the bus-level pull path (plain +
keyed partition-granular) that the transport ``steal=`` flag switches on.
"""
from __future__ import annotations

import socket
import time

import pytest

from repro.core import FieldSpec, MessageBus, StreamSchema
from repro.core.compression import available_codecs, train_dictionary
from repro.core.delivery import Group, Keyed
from repro.core.transport import (DEFAULT_MAX_FRAME_MSGS, PROTO_VERSION,
                                  SUPPORTED_PROTOS, BusServer, RemoteBus,
                                  _encode_frame, pack_frame, read_frame,
                                  unpack_frame)

SCHEMA = StreamSchema.of(k=FieldSpec("str"), i=FieldSpec("int"))


def _served_bus(**server_kw):
    bus = MessageBus()
    bus.register_subject("t", SCHEMA)
    server = BusServer(bus, **server_kw)
    tok = bus.issue_token("pub", ["t"])
    return bus, server, tok


def _drain(sub, n, timeout=5.0):
    got, deadline = [], time.monotonic() + timeout
    while len(got) < n and time.monotonic() < deadline:
        got.extend(sub.next_batch(n - len(got), timeout=0.1))
    return got


def _probe_until_delivery(bus, tok, sub, timeout=10.0):
    """Publish probes until one arrives at ``sub`` — the only reliable way
    to detect a finished re-join: membership on a fire-and-forget subject
    has a no-member window after a drop, and probes published into it are
    dropped by design (exactly like any crashed worker's backlog)."""
    deadline = time.monotonic() + timeout
    i = 0
    while time.monotonic() < deadline:
        bus.publish("t", {"k": "probe", "i": i}, token=tok)
        i += 1
        if sub.next_batch(16, timeout=0.2):
            return
    raise AssertionError("resubscribed member never received a probe")


# ---------------------------------------------------------------------------
# Frame codecs: v2 envelope round-trips
# ---------------------------------------------------------------------------

class TestFrameCodecs:
    V2_FRAMES = [
        {"op": "hello", "rid": 0, "peer": "w", "proto": 2,
         "codecs": ["zstd", "zlib"], "max_frame_msgs": 64},
        {"op": "msgs", "ms": [[3, {"subject": "t", "seq": 7,
                                   "payload": {"k": "a", "i": 1}}],
                              [3, {"subject": "t", "seq": 8,
                                   "payload": {"k": "b", "i": 2}}]]},
        {"op": "pubs", "rid": 9, "subject": "t", "token": "tok",
         "payloads": [{"k": "a", "i": 0}, {"k": "a", "i": 1}]},
        {"op": "dict", "data": b"\x00\x01dictionary-bytes"},
    ]

    @pytest.mark.parametrize("frame", V2_FRAMES,
                             ids=[f["op"] for f in V2_FRAMES])
    def test_v2_frames_roundtrip_on_zlib(self, frame):
        # zlib is the leg every peer can read (the hello itself rides it)
        data, raw = _encode_frame(frame, codec="zlib")
        assert unpack_frame(data[4:]) == frame
        assert len(raw) > 0  # the wire_ratio denominator is observable

    def test_wire_blob_is_tagged_and_smaller_than_raw_when_redundant(self):
        frame = {"op": "msgs",
                 "ms": [[1, {"payload": {"k": "key-xyz", "i": n}}]
                        for n in range(64)]}
        data, raw = _encode_frame(frame, codec="zlib")
        assert len(data) < len(raw)  # redundancy actually compresses

    @pytest.mark.skipif("zstd" not in available_codecs(),
                        reason="zstandard not installed")
    def test_zstd_dictionary_roundtrip(self):
        samples = [b'{"k": "key-%02d", "i": 1}' % n for n in range(64)]
        d = train_dictionary(samples)
        assert d
        frame = {"op": "msgs", "ms": [[1, {"payload": {"k": "key-01"}}]]}
        data, _ = _encode_frame(frame, codec="zstd", dictionary=d)
        assert unpack_frame(data[4:], dictionary=d) == frame
        with pytest.raises(Exception):
            unpack_frame(data[4:])  # DXZ2 unreadable without the dictionary


# ---------------------------------------------------------------------------
# Hello negotiation: v2, down to zlib, raw v1, proto=1
# ---------------------------------------------------------------------------

class TestNegotiation:
    def test_v2_client_negotiates_proto_codec_and_frame_cap(self):
        bus, server, tok = _served_bus(max_frame_msgs=32)
        try:
            rb = RemoteBus(server.address, peer="w", max_frame_msgs=64)
            stats = rb.transport_stats()
            assert stats["proto"] == PROTO_VERSION == 2
            assert stats["codec"] == available_codecs()[0]
            peer = server.stats()["peers"]["w"]
            assert peer["proto"] == 2
            assert peer["codec"] == stats["codec"]
            assert peer["max_frame_msgs"] == 32  # min(server, client)
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_zlib_only_client_negotiates_down(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="old", codecs=["zlib"])
            assert rb.transport_stats()["proto"] == 2
            assert rb.transport_stats()["codec"] == "zlib"
            assert server.stats()["peers"]["old"]["codec"] == "zlib"
            # and the connection actually works end to end
            sub = rb.subscribe("t", token=rb.issue_token("old", ["t"]),
                               name="old")
            rb.publish("t", {"k": "a", "i": 1}, token=tok)
            got = _drain(sub, 1, timeout=5.0)
            assert got and got[0].payload["i"] == 1
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_raw_v1_peer_without_hello_still_served(self):
        """A pre-PR-9 peer never sends hello: the server must keep treating
        it as proto 1 — per-message ``msg`` frames, zlib, no dictionary."""
        bus, server, tok = _served_bus()
        try:
            local = bus.subscribe("t", token=tok, name="chk")
            sock = socket.create_connection(server.address, timeout=5)
            sock.sendall(pack_frame({"op": "publish", "rid": 1,
                                     "subject": "t", "token": tok,
                                     "payload": {"k": "a", "i": 7}}))
            reply, _, _ = read_frame(sock)
            assert reply["ok"] is True
            m = local.next(timeout=5.0)
            assert m is not None and m.payload["i"] == 7
            sock.close()
        finally:
            server.close()
            bus.close()

    def test_proto1_hello_accepted_with_v1_reply(self):
        bus, server, _ = _served_bus()
        try:
            sock = socket.create_connection(server.address, timeout=5)
            sock.sendall(pack_frame({"op": "hello", "rid": 0, "peer": "v1",
                                     "proto": 1}))
            reply, _, _ = read_frame(sock)
            assert reply["ok"] is True
            assert reply["proto"] == 1
            assert 1 in SUPPORTED_PROTOS and 2 in SUPPORTED_PROTOS
            sock.close()
        finally:
            server.close()
            bus.close()


# ---------------------------------------------------------------------------
# Batched publish (pubs) and coalesced delivery (msgs)
# ---------------------------------------------------------------------------

class TestCoalescing:
    def test_publish_many_is_ordered_and_acknowledged(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="w")
            local = bus.subscribe("t", token=tok, name="chk", maxsize=512)
            msgs = rb.publish_many(
                "t", [{"k": "a", "i": i} for i in range(100)], token=tok)
            assert [m.payload["i"] for m in msgs] == list(range(100))
            seqs = [m.seq for m in msgs]
            assert seqs == sorted(seqs)
            got = _drain(local, 100)
            assert [m.payload["i"] for m in got] == list(range(100))
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_publish_many_falls_back_per_message_on_v1(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="w")
            with rb._lock:
                rb._proto = 1  # as if the server had answered a v1 hello
            local = bus.subscribe("t", token=tok, name="chk")
            msgs = rb.publish_many(
                "t", [{"k": "a", "i": i} for i in range(5)], token=tok)
            assert [m.payload["i"] for m in msgs] == list(range(5))
            assert len(_drain(local, 5)) == 5
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_backlog_drains_in_coalesced_frames(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="w")
            sub = rb.subscribe("t", token=rb.issue_token("w", ["t"]),
                               name="w", maxsize=512)
            rb.publish_many(
                "t", [{"k": "a", "i": i} for i in range(256)], token=tok)
            got = _drain(sub, 256)
            assert [m.payload["i"] for m in got] == list(range(256))
            stats = rb.transport_stats()
            assert stats["frames_coalesced"] > 0
            # far fewer frames than messages: the backlog rode multi-
            # message frames, not 256 per-message ones
            assert stats["frames_in"] < 256
            assert server.stats()["peers"]["w"]["frames_coalesced"] > 0
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_per_peer_byte_counters_track_wire_and_raw(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="w")
            rb.publish_many(
                "t", [{"k": "key-%d" % (i % 4), "i": i} for i in range(64)],
                token=tok)
            cs = rb.transport_stats()
            assert cs["bytes_out"] > 0 and cs["raw_bytes_out"] > 0
            assert cs["wire_ratio"] == round(
                cs["raw_bytes_out"] / cs["bytes_out"], 4)
            ss = server.stats()["peers"]["w"]
            assert ss["bytes_in"] > 0 and ss["raw_bytes_in"] > 0
            # the redundant burst must compress: raw strictly above wire
            assert ss["raw_bytes_in"] > ss["bytes_in"]
            rb.close()
        finally:
            server.close()
            bus.close()


# ---------------------------------------------------------------------------
# resubscribe=True across a reconnect storm
# ---------------------------------------------------------------------------

class TestResubscribe:
    def test_reconnect_storm_restores_membership_and_order(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="stormy", resubscribe=True,
                           hb_interval=0.1, hb_timeout=2.0)
            sub = rb.subscribe("t", token=rb.issue_token("stormy", ["t"]),
                               group="g", name="stable-1")
            for round_no in range(1, 4):
                rb._drop_connection(f"storm {round_no}")
                _probe_until_delivery(bus, tok, sub)
                assert rb.transport_stats()["reconnects"] == round_no
                assert not sub.closed  # kept open across every drop
            # steady state: ordered delivery, exactly one ring identity
            for i in range(20):
                bus.publish("t", {"k": "steady", "i": i}, token=tok)
            got = [m for m in _drain(sub, 20, timeout=10.0)
                   if m.payload["k"] == "steady"]
            assert [m.payload["i"] for m in got] == list(range(20))
            info = bus.group_info("t", "g")
            assert info["members"] == ["stable-1"]
            assert rb.transport_stats()["resubscribe"] is True
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_default_remains_explicit_membership(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="plain")
            sub = rb.subscribe("t", token=rb.issue_token("plain", ["t"]),
                               group="g", name="m1")
            rb._drop_connection("blip")
            deadline = time.monotonic() + 5.0
            while not sub.closed and time.monotonic() < deadline:
                time.sleep(0.05)
            assert sub.closed  # no silent re-join without resubscribe=True
            rb.close()
        finally:
            server.close()
            bus.close()


# ---------------------------------------------------------------------------
# Pull-based work stealing on the bus (what transport steal= switches on)
# ---------------------------------------------------------------------------

class TestStealing:
    def test_idle_group_member_steals_backlog(self):
        bus = MessageBus()
        bus.register_subject("t", SCHEMA)
        tok = bus.issue_token("pub", ["t"])
        busy = bus.subscribe("t", token=tok, name="busy",
                             policy=Group("g", steal=True))
        idle = bus.subscribe("t", token=tok, name="idle",
                             policy=Group("g", steal=True))
        for i in range(40):
            bus.publish("t", {"k": "a", "i": i}, token=tok)
        # only the idle member consumes: nearly everything "busy" was dealt
        # must arrive by stealing — only its mailbox HEAD may stay behind
        # (the item the victim could already be processing is never moved)
        got = _drain(idle, 39)
        assert len(got) >= 39
        got += idle.next_batch(1, timeout=0.2)  # in case the head moved too
        leftover = busy.next_batch(40, timeout=0.1)
        assert len(leftover) <= 1
        seen = sorted(m.payload["i"] for m in got + leftover)
        assert seen == list(range(40))
        info = bus.group_info("t", "g")
        assert info["steal_enabled"] is True
        assert info["stolen"] > 0
        bus.close()

    def test_keyed_steal_moves_whole_partitions_in_order(self):
        bus = MessageBus()
        bus.register_subject("t", SCHEMA)
        tok = bus.issue_token("pub", ["t"])
        s1 = bus.subscribe("t", token=tok, name="m1",
                           policy=Keyed("kg", "k", steal=True))
        s2 = bus.subscribe("t", token=tok, name="m2",
                           policy=Keyed("kg", "k", steal=True))
        per_key: dict[str, int] = {}
        for i in range(120):
            k = f"key-{i % 8}"
            bus.publish("t", {"k": k, "i": per_key.get(k, 0)}, token=tok)
            per_key[k] = per_key.get(k, 0) + 1
        # m1 never consumes: its partitions' backlogs move to m2 WHOLE
        got = _drain(s2, 120)
        assert len(got) == 120
        last: dict[str, int] = {}
        for m in got:
            assert m.payload["i"] == last.get(m.payload["k"], -1) + 1
            last[m.payload["k"]] = m.payload["i"]
        info = bus.group_info("t", "kg")
        assert info["stolen"] > 0
        assert info["stolen_partitions"]  # ownership overrides recorded
        assert set(info["stolen_partitions"].values()) == {"m2"}
        bus.close()

    def test_stealing_is_off_by_default_and_switchable(self):
        bus = MessageBus()
        bus.register_subject("t", SCHEMA)
        tok = bus.issue_token("pub", ["t"])
        s1 = bus.subscribe("t", token=tok, group="g", name="m1")
        s2 = bus.subscribe("t", token=tok, group="g", name="m2")
        for i in range(20):
            bus.publish("t", {"k": "a", "i": i}, token=tok)
        got = _drain(s2, 20, timeout=1.0)
        assert len(got) < 20  # m1's share stays pinned: no stealing
        assert bus.group_info("t", "g")["stolen"] == 0
        assert bus.enable_stealing("t", "g") is True
        got += _drain(s2, 19 - len(got))
        got += s1.next_batch(20, timeout=0.1)  # at most m1's retained head
        assert sorted(m.payload["i"] for m in got) == list(range(20))
        assert bus.group_info("t", "g")["stolen"] > 0
        assert bus.enable_stealing("t", "nope") is False
        bus.close()

    def test_steal_flag_propagates_over_the_wire(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="w")
            wtok = rb.issue_token("w", ["t"])
            subs = [rb.subscribe("t", token=wtok, name=f"m{i}",
                                 policy=Group("g", steal=True))
                    for i in range(2)]
            info = bus.group_info("t", "g")
            assert info["steal_enabled"] is True
            rb.close()
        finally:
            server.close()
            bus.close()


# ---------------------------------------------------------------------------
# Property: coalesced frames x steals x mid-run kill keep per-key order
# ---------------------------------------------------------------------------

def _wire_kill_case(n_keys: int, per_key: int, max_frame_msgs: int,
                    kill_after: int, steal: bool) -> None:
    """One exactly-once scenario: two keyed remote consumers under a given
    coalescing cap (and optionally stealing), the first one dropped without
    a goodbye after ``kill_after`` effect-then-acknowledged messages.  The
    union of both record streams must equal the published set exactly once,
    with every key's ``i`` strictly increasing within each member's stream
    — whatever interleaving of multi-message frames, partition steals, and
    the re-home the draw produced."""
    bus = MessageBus(default_queue_size=4096)
    bus.register_subject("p", SCHEMA)
    server = BusServer(bus, max_frame_msgs=max_frame_msgs, hb_timeout=8.0)
    tok = bus.issue_token("pub", ["p"])
    rb1 = rb2 = None
    try:
        rb1 = RemoteBus(server.address, peer="p1")
        rb2 = RemoteBus(server.address, peer="p2")
        s1 = rb1.subscribe("p", token=rb1.issue_token("p1", ["p"]),
                           name="v1", policy=Keyed("pg", "k", steal=steal),
                           auto_ack=False)
        s2 = rb2.subscribe("p", token=rb2.issue_token("p2", ["p"]),
                           name="v2", policy=Keyed("pg", "k", steal=steal),
                           auto_ack=False)
        published: set[tuple[str, int]] = set()
        for n in range(n_keys * per_key):
            k = f"key-{n % n_keys}"
            i = n // n_keys
            bus.publish("p", {"k": k, "i": i}, token=tok)
            published.add((k, i))
        rec1: list[tuple[str, int]] = []
        rec2: list[tuple[str, int]] = []

        def pump(sub, rec, cap):
            msgs = sub.next_batch(cap, timeout=0.2)
            rec += [(m.payload["k"], m.payload["i"]) for m in msgs]
            sub.ack(len(msgs))  # effect recorded -> acknowledge
            return len(msgs)

        # phase 1: both consume; the victim stops at its kill point (or
        # when the survivor already drained everything — the ring may have
        # dealt the victim nothing)
        deadline = time.monotonic() + 20.0
        while time.monotonic() < deadline:
            pump(s1, rec1, min(8, max(1, kill_after - len(rec1))))
            pump(s2, rec2, 8)
            if len(rec1) >= kill_after or set(rec1) | set(rec2) >= published:
                break
        rb1._drop_connection("property kill")  # crash: no goodbye, no ack
        # phase 2: the survivor must end up with every remaining message
        while set(rec1) | set(rec2) < published \
                and time.monotonic() < deadline:
            pump(s2, rec2, 64)
        union = rec1 + rec2
        assert set(union) == published, "lost messages across the kill"
        assert len(union) == len(set(union)), "double delivery"
        for rec in (rec1, rec2):
            last: dict[str, int] = {}
            for k, i in rec:
                assert i > last.get(k, -1), \
                    f"per-key order break: {k} saw {i} after {last[k]}"
                last[k] = i
    finally:
        if rb1 is not None:
            rb1.close()
        if rb2 is not None:
            rb2.close()
        server.close()
        bus.close()


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(n_keys=st.integers(min_value=1, max_value=6),
           per_key=st.integers(min_value=2, max_value=20),
           max_frame_msgs=st.sampled_from([1, 2, 64]),
           kill_after=st.integers(min_value=1, max_value=20),
           steal=st.booleans())
    def test_kill_under_coalescing_keeps_per_key_order(
            n_keys, per_key, max_frame_msgs, kill_after, steal):
        _wire_kill_case(n_keys, per_key, max_frame_msgs, kill_after, steal)
except ImportError:
    # minimal-deps leg: a fixed seed corpus covering the same axes —
    # per-message framing, deep coalescing, stealing on and off
    _SEED_CASES = [
        (4, 10, 64, 5, False),
        (3, 12, 1, 7, True),
        (6, 8, 64, 3, True),
    ]

    @pytest.mark.parametrize("case", _SEED_CASES,
                             ids=["coalesced", "permsg-steal", "steal"])
    def test_kill_under_coalescing_keeps_per_key_order(case):
        _wire_kill_case(*case)
