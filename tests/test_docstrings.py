"""Public-API docstring coverage — the enforcement half of docs/.

Every symbol exported from ``repro.core`` (its ``__all__``) is the
platform's public surface; each must carry a non-empty docstring, and so
must the public methods of the classes a developer actually drives
day-to-day (``App``, ``StreamHandle``, ``MessageBus``, ``KeyedStore``,
``Operator``).  A new export without documentation fails tier-1, not
review.
"""
from __future__ import annotations

import inspect

import pytest

import repro.core as core
from repro.core import App, KeyedStore, MessageBus, Operator, StreamHandle


def _has_doc(obj) -> bool:
    doc = inspect.getdoc(obj)
    return bool(doc and doc.strip())


def test_core_all_symbols_are_documented():
    missing = []
    for name in core.__all__:
        obj = getattr(core, name)
        if callable(obj) or inspect.ismodule(obj):
            if not _has_doc(obj):
                missing.append(name)
    assert not missing, (
        f"exported without a docstring: {sorted(missing)} — every symbol in "
        f"repro.core.__all__ is public API and must document itself")


def test_core_all_is_complete_and_resolvable():
    for name in core.__all__:
        assert hasattr(core, name), f"__all__ exports missing symbol {name}"


@pytest.mark.parametrize("cls", [App, StreamHandle, MessageBus, KeyedStore,
                                 Operator])
def test_public_methods_are_documented(cls):
    missing = []
    for name, member in inspect.getmembers(cls):
        if name.startswith("_"):
            continue
        if not (inspect.isfunction(member) or inspect.ismethod(member)
                or isinstance(inspect.getattr_static(cls, name), property)):
            continue
        if not _has_doc(member if not isinstance(
                inspect.getattr_static(cls, name), property)
                else inspect.getattr_static(cls, name)):
            missing.append(f"{cls.__name__}.{name}")
    assert not missing, (
        f"public methods without docstrings: {sorted(missing)}")
