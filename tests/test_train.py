"""Training integration: loss goes down; optimizer features; compression."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig

RUN = RunConfig(attention_impl="chunked", attention_chunk=32, remat="none",
                learning_rate=1e-2, warmup_steps=2)


def test_loss_decreases(tmp_path):
    cfg = get_smoke_config("minitron-4b")
    tcfg = TrainerConfig(global_batch=4, seq_len=48, ckpt_every=100,
                         total_steps=40, workdir=str(tmp_path))
    tr = Trainer(cfg, RUN, tcfg)
    tr.init_or_restore()
    ms = tr.run_steps(12)
    tr.close()
    first = np.mean([m["loss"] for m in ms[:3]])
    last = np.mean([m["loss"] for m in ms[-3:]])
    assert last < first, (first, last)


@pytest.mark.parametrize("mode", ["bf16", "int8_ef"])
def test_gradient_compression_still_converges(mode, tmp_path):
    import dataclasses
    run = dataclasses.replace(RUN, grad_compression=mode)
    cfg = get_smoke_config("mamba2-370m")
    tcfg = TrainerConfig(global_batch=4, seq_len=32, ckpt_every=100,
                         total_steps=40, workdir=str(tmp_path))
    tr = Trainer(cfg, run, tcfg)
    tr.init_or_restore()
    ms = tr.run_steps(10)
    tr.close()
    assert np.mean([m["loss"] for m in ms[-3:]]) < \
        np.mean([m["loss"] for m in ms[:3]])


def test_int8_error_feedback_reduces_bias():
    """EF accumulates quantization residual: mean dequantized grad over many
    steps approaches the true mean (bias -> 0), unlike naive quantization."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)) * 1e-3 + 2e-4)
    err = jnp.zeros_like(g_true)
    acc_ef = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, err = opt.compress_grad(g_true, err, "int8_ef")
        acc_ef += deq
    bias_ef = float(jnp.abs(acc_ef / 50 - g_true).mean())
    deq_naive, _ = opt.compress_grad(g_true, None, "int8_ef")
    bias_naive = float(jnp.abs(deq_naive - g_true).mean())
    assert bias_ef < bias_naive * 0.2, (bias_ef, bias_naive)


def test_lr_schedule_shape():
    import dataclasses
    run = dataclasses.replace(RUN, warmup_steps=10, learning_rate=1.0)
    lrs = [float(opt.lr_schedule(jnp.int32(s), run, total_steps=100))
           for s in range(0, 101, 10)]
    assert lrs[0] < lrs[1]                 # warmup rising
    assert max(lrs) <= 1.0
    assert lrs[-1] < lrs[2]                # cosine decaying


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    run = RunConfig(grad_clip=1.0, learning_rate=0.0, weight_decay=0.0)
    state = opt.init_opt_state(params, run)
    big = {"w": jnp.full((4,), 100.0)}
    _, state2, m = opt.adamw_update(big, params, state, run)
    assert float(m["grad_norm"]) > 1.0
    # post-clip first moment bounded by (1-b1) * clip
    assert float(jnp.abs(state2["m"]["w"]).max()) <= (1 - run.beta1) * 1.0 + 1e-6


def test_master_weights_roundtrip():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    run = RunConfig(learning_rate=1e-4, weight_decay=0.0)
    state = opt.init_opt_state(params, run, master_weights=True)
    g = {"w": jnp.full((8,), 1e-3, jnp.bfloat16)}
    p2, s2, _ = opt.adamw_update(g, params, state, run)
    assert s2["master"]["w"].dtype == jnp.float32
    assert p2["w"].dtype == jnp.bfloat16
    # master holds more precision than bf16 params
    assert float(jnp.abs(s2["master"]["w"] - 1.0).max()) > 0
