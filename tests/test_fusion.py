"""Chain fusion: the compiler pass between the fluent API and the runtime.

Contracts:
(a) build-time: maximal linear DEVICE segments collapse into ONE fused AU +
    stream; interior streams never become bus subjects; declared AUs stay in
    the catalog while orphaned synthetic combinator AUs are collected;
(b) results are bit-identical to per-hop bus execution — on the jitted
    device program AND on the host-composed fallback (no jax / untraceable
    stage / JIT_MODE never);
(c) fusion barriers: window combinators, multi-input fuse, multi-subscriber
    taps, explicit .tap(), fixed_instances > 1;
(d) `.via(..., upgrade=...)` re-composes to the Operator's §4 upgrade path.
"""
import time

import numpy as np
import pytest

from repro.core import (AnalyticsUnitSpec, App, Application, DriverSpec,
                        Placement, SensorSpec, StreamSchema, StreamSpec,
                        connect, drain, fuse_application, plan_segments)
from repro.core import fusion

TEN = StreamSchema.device(x=((8, 8), "float32"))


def _frames(n):
    return [{"x": np.full((8, 8), float(i), np.float32)} for i in range(n)]


def _chain_app(n=10) -> App:
    """sensor -> x*2 -> keep x[0,0] < 16 -> x+1 -> -x   (all exact in f32)."""
    app = App("chain")

    @app.driver(emits=TEN)
    def src(ctx, n=10):
        return iter(_frames(n))

    (app.sense("raw", src, n=n)
        .map(lambda p: {"x": p["x"] * 2}, emits=TEN, device=True, name="m1")
        .filter(lambda p: p["x"][0, 0] < 16.0, device=True, name="f1")
        .map(lambda p: {"x": p["x"] + 1}, emits=TEN, device=True, name="m2")
        .map(lambda p: {"x": -p["x"]}, emits=TEN, device=True, name="exit"))
    return app


def _run(app: App, stream: str, n: int, *, fuse: bool = True) -> list:
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False, fuse=fuse)
        sub = op.subscribe(stream)
        op.start_pending_sensors()
        return [m.payload for m in drain(sub, n, timeout=30)]


# ---------------------------------------------------------------------------
# (a) build-time collapse
# ---------------------------------------------------------------------------

def test_device_chain_collapses_to_one_fused_unit():
    built = _chain_app().build()
    assert [s.name for s in built.streams] == ["exit"]
    assert built.streams[0].inputs == ("raw",)      # entry edge on the bus
    fused = [a for a in built.analytics_units if a.fused_stages]
    assert len(fused) == 1
    assert fused[0].name == "exit.fused"
    assert fused[0].placement is Placement.DEVICE
    assert fused[0].fused_stages == ("m1.map", "f1.filter", "m2.map",
                                     "exit.map")
    # orphaned synthetic combinator AUs are collected
    assert [a.name for a in built.analytics_units] == ["exit.fused"]
    # the unfused build keeps every hop
    unfused = _chain_app().build(fuse=False)
    assert [s.name for s in unfused.streams] == ["m1", "f1", "m2", "exit"]


def test_single_device_stage_is_not_fused():
    app = App("single")

    @app.driver(emits=TEN)
    def src(ctx):
        return iter(())

    app.sense("raw", src).map(lambda p: p, emits=TEN, device=True, name="m1")
    built = app.build()
    assert [s.name for s in built.streams] == ["m1"]
    assert not any(a.fused_stages for a in built.analytics_units)


def test_fusion_works_on_v1_spec_graphs():
    """The pass runs on the compiled Application, so v1 apps benefit too."""
    app = Application(name="v1")
    app.driver(DriverSpec(name="d", logic=lambda ctx: iter(()),
                          output_schema=TEN))
    for name in ("a", "b"):
        app.analytics_unit(AnalyticsUnitSpec(
            name=name, logic=lambda ctx: (lambda s, p: p),
            placement=Placement.DEVICE, min_instances=1, max_instances=4))
    app.sensor(SensorSpec(name="src", driver="d"))
    app.stream(StreamSpec(name="sa", analytics_unit="a", inputs=("src",)))
    app.stream(StreamSpec(name="sb", analytics_unit="b", inputs=("sa",)))
    assert [[s.name for s in seg] for seg in plan_segments(app)] == \
        [["sa", "sb"]]
    fused = fuse_application(app)
    assert [s.name for s in fused.streams] == ["sb"]
    unit = next(a for a in fused.analytics_units if a.fused_stages)
    assert unit.fused_stages == ("a", "b")
    # declared stage AUs stay in the operator catalog
    assert {"a", "b"} <= {a.name for a in fused.analytics_units}


def test_fused_unit_folds_stage_scaling_bounds():
    app = Application(name="scale")
    app.driver(DriverSpec(name="d", logic=lambda ctx: iter(()),
                          output_schema=TEN))
    app.analytics_unit(AnalyticsUnitSpec(
        name="a", logic=lambda ctx: (lambda s, p: p),
        placement=Placement.DEVICE, min_instances=1, max_instances=8))
    app.analytics_unit(AnalyticsUnitSpec(
        name="b", logic=lambda ctx: (lambda s, p: p),
        placement=Placement.DEVICE, min_instances=2, max_instances=4))
    app.sensor(SensorSpec(name="src", driver="d"))
    app.stream(StreamSpec(name="sa", analytics_unit="a", inputs=("src",)))
    app.stream(StreamSpec(name="sb", analytics_unit="b", inputs=("sa",)))
    unit = next(a for a in fuse_application(app).analytics_units
                if a.fused_stages)
    # autoscaled as a WHOLE: the segment's envelope, not per-hop counts
    assert (unit.min_instances, unit.max_instances) == (2, 4)
    # contradictory envelopes (a floor above another stage's ceiling) clamp
    # the floor — no stage ever runs above its declared max_instances
    app.analytics_units[0] = AnalyticsUnitSpec(
        name="a", logic=lambda ctx: (lambda s, p: p),
        placement=Placement.DEVICE, min_instances=6, max_instances=8)
    unit = next(u for u in fuse_application(app).analytics_units
                if u.fused_stages)
    assert (unit.min_instances, unit.max_instances) == (4, 4)


# ---------------------------------------------------------------------------
# (b) bit-identical execution on every path
# ---------------------------------------------------------------------------

def _assert_identical(a: list, b: list) -> None:
    assert len(a) == len(b)
    for pa, pb in zip(a, b):
        assert pa.keys() == pb.keys()
        assert np.array_equal(pa["x"], pb["x"])
        assert np.asarray(pa["x"]).dtype == np.asarray(pb["x"]).dtype


def test_fused_jit_program_bit_identical_to_bus(monkeypatch):
    monkeypatch.delenv("DATAX_FUSION_JIT", raising=False)
    monkeypatch.setattr(fusion, "JIT_MODE", "always")
    fused = _run(_chain_app(), "exit", 8, fuse=True)
    unfused = _run(_chain_app(), "exit", 8, fuse=False)
    _assert_identical(fused, unfused)


def test_fused_host_chain_bit_identical_to_bus(monkeypatch):
    monkeypatch.setattr(fusion, "JIT_MODE", "never")
    fused = _run(_chain_app(), "exit", 8, fuse=True)
    unfused = _run(_chain_app(), "exit", 8, fuse=False)
    _assert_identical(fused, unfused)


def test_no_jax_falls_back_to_host_chain(monkeypatch):
    monkeypatch.setattr(fusion, "_HAS_JAX", False)
    app = _chain_app()
    built = app.build()
    assert any(a.fused_stages for a in built.analytics_units)  # still fuses
    fused = _run(_chain_app(), "exit", 8, fuse=True)
    unfused = _run(_chain_app(), "exit", 8, fuse=False)
    _assert_identical(fused, unfused)


def test_scalar_outputs_typed_identically_on_jit_and_host(monkeypatch):
    """A reduction to 0-d must come back as a numpy scalar on the jitted
    path, exactly as numpy produces on the host path — the jit path must
    never be *more lenient* (e.g. python floats passing a FieldSpec that
    numpy scalars fail) than per-hop bus execution."""
    monkeypatch.delenv("DATAX_FUSION_JIT", raising=False)

    def build():
        app = App("scalars")

        @app.driver(emits=TEN)
        def src(ctx, n=3):
            return iter(_frames(n))

        (app.sense("raw", src)
            .map(lambda p: {"x": p["x"] * 2}, emits=TEN, device=True,
                 name="m1")
            .map(lambda p: {"s": p["x"].sum()}, device=True, name="exit"))
        return app

    monkeypatch.setattr(fusion, "JIT_MODE", "always")
    jit_out = _run(build(), "exit", 3)
    monkeypatch.setattr(fusion, "JIT_MODE", "never")
    host_out = _run(build(), "exit", 3)
    for pj, ph in zip(jit_out, host_out):
        assert type(pj["s"]) is type(ph["s"]) is np.float32
        assert pj["s"] == ph["s"]


def test_untraceable_stage_degrades_to_host_per_message(monkeypatch):
    """float(tracer) raises under jit -> the unit drops to the host chain."""
    monkeypatch.setattr(fusion, "JIT_MODE", "always")
    app = App("impure")

    @app.driver(emits=TEN)
    def src(ctx, n=4):
        return iter(_frames(n))

    (app.sense("raw", src)
        .map(lambda p: {"x": p["x"] * 2}, emits=TEN, device=True, name="m1")
        .map(lambda p: {"x": p["x"] * (2.0 if float(p["x"].sum()) >= 0 else 1.0)},
             emits=TEN, device=True, name="exit"))
    out = _run(app, "exit", 4)
    assert [p["x"][0, 0] for p in out] == [0.0, 4.0, 8.0, 12.0]


def test_declared_device_au_joins_segment_host_composed():
    app = App("via-dev")

    @app.driver(emits=TEN)
    def src(ctx, n=5):
        return iter(_frames(n))

    @app.analytics_unit(expects=(TEN,), emits=TEN,
                        placement=Placement.DEVICE)
    def halver(ctx):
        return lambda s, p: {"x": p["x"] * 0.5}

    (app.sense("raw", src)
        .map(lambda p: {"x": p["x"] * 2}, emits=TEN, device=True, name="m1")
        .via(halver, name="exit"))
    built = app.build()
    unit = next(a for a in built.analytics_units if a.fused_stages)
    assert unit.fused_stages == ("m1.map", "halver")
    out = _run(app, "exit", 5)
    assert [p["x"][0, 0] for p in out] == [0.0, 1.0, 2.0, 3.0, 4.0]


def test_fused_unit_jit_warmup_recorded(monkeypatch):
    """All-device entry schema -> the unit compiles before the first message
    and the compile cost lands in warmup_s, not the latency EWMA."""
    if not fusion.jax_available():
        pytest.skip("warmup compiles a jit program; needs jax")
    monkeypatch.setattr(fusion, "JIT_MODE", "always")
    app = _chain_app()
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        deadline = time.monotonic() + 10
        warmup = 0.0
        while warmup == 0.0 and time.monotonic() < deadline:
            handles = op.executor.instances_of("exit")
            if handles:
                warmup = handles[0].sidecar.metrics()["warmup_s"]
            time.sleep(0.02)
    assert warmup > 0.0


# ---------------------------------------------------------------------------
# (c) fusion barriers
# ---------------------------------------------------------------------------

def test_window_is_a_barrier():
    app = App("win")

    @app.driver(emits=TEN)
    def src(ctx):
        return iter(())

    (app.sense("raw", src)
        .map(lambda p: p, emits=TEN, device=True, name="a")
        .map(lambda p: p, emits=TEN, device=True, name="b")
        .window(2, name="w")
        .map(lambda p: p, device=True, name="c")
        .map(lambda p: p, device=True, name="d"))
    built = app.build()
    assert [s.name for s in built.streams] == ["w", "b", "d"]
    fused = {a.name: a.fused_stages for a in built.analytics_units
             if a.fused_stages}
    assert fused == {"b.fused": ("a.map", "b.map"),
                     "d.fused": ("c.map", "d.map")}


def test_multi_input_fuse_is_a_barrier():
    from repro.core import StreamHandle
    app = App("join")

    @app.driver(emits=TEN)
    def src(ctx):
        return iter(())

    a = app.sense("ra", src).map(lambda p: p, emits=TEN, device=True,
                                 name="a")
    b = app.sense("rb", src).map(lambda p: p, emits=TEN, device=True,
                                 name="b")
    StreamHandle.fuse(a, b, with_=lambda x, y: x, emits=TEN, name="joined")
    built = app.build()
    assert not any(u.fused_stages for u in built.analytics_units)
    assert {s.name for s in built.streams} == {"a", "b", "joined"}


def test_multi_subscriber_tap_splits_segment():
    app = App("tee")

    @app.driver(emits=TEN)
    def src(ctx):
        return iter(())

    @app.actuator(expects=(TEN,))
    def sink(ctx):
        return lambda s, p: None

    mid = app.sense("raw", src).map(lambda p: p, emits=TEN, device=True,
                                    name="mid")
    mid.map(lambda p: p, emits=TEN, device=True, name="out")
    mid >> app.gadget("g", sink)               # second consumer of `mid`
    built = app.build()
    assert not any(u.fused_stages for u in built.analytics_units)
    assert {s.name for s in built.streams} == {"mid", "out"}


def test_explicit_tap_is_a_barrier_and_stays_subscribable():
    app = App("tapped")

    @app.driver(emits=TEN)
    def src(ctx, n=3):
        return iter(_frames(n))

    (app.sense("raw", src)
        .map(lambda p: {"x": p["x"] * 2}, emits=TEN, device=True, name="mid")
        .tap()
        .map(lambda p: {"x": p["x"] + 1}, emits=TEN, device=True, name="out"))
    built = app.build()
    assert not any(u.fused_stages for u in built.analytics_units)
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        sub = op.subscribe("mid")              # the §3 reuse surface survives
        op.start_pending_sensors()
        assert [m.payload["x"][0, 0] for m in drain(sub, 3, timeout=30)] == \
            [0.0, 2.0, 4.0]


def test_fixed_instances_above_one_is_a_barrier():
    app = Application(name="fixed")
    app.driver(DriverSpec(name="d", logic=lambda ctx: iter(()),
                          output_schema=TEN))
    for name in ("a", "b"):
        app.analytics_unit(AnalyticsUnitSpec(
            name=name, logic=lambda ctx: (lambda s, p: p),
            placement=Placement.DEVICE))
    app.sensor(SensorSpec(name="src", driver="d"))
    app.stream(StreamSpec(name="sa", analytics_unit="a", inputs=("src",),
                          fixed_instances=2))
    app.stream(StreamSpec(name="sb", analytics_unit="b", inputs=("sa",)))
    assert plan_segments(app) == []
