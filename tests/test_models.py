"""Per-architecture smoke tests: reduced same-family configs, one forward /
train step on CPU, asserting output shapes + no NaNs (assignment req.)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.configs.base import RunConfig
from repro.train import optimizer as opt
from repro.train import steps

RUN = RunConfig(attention_impl="chunked", attention_chunk=16, remat="none",
                microbatches=1,
                # big enough that one update exceeds a bf16 ulp on every arch
                learning_rate=1e-2, warmup_steps=1)
KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model)).astype(cfg.activation_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    params = models.init(KEY, cfg)
    logits, aux = models.forward(params, _batch(cfg), cfg, RUN)
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    params = models.init(KEY, cfg)
    opt_state = opt.init_opt_state(params, RUN)
    train_step = jax.jit(steps.make_train_step(cfg, RUN))
    params2, opt_state2, metrics = train_step(params, opt_state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_smoke_config(arch)
    params = models.init(KEY, cfg)
    cache = models.init_cache(cfg, B, 64)
    batch = {"tokens": jax.random.randint(KEY, (B, 1), 0, cfg.vocab),
             "seq_lens": jnp.zeros((B,), jnp.int32)}
    logits, cache2 = models.decode_step(params, cache, batch, cfg, RUN)
    assert logits.shape == (B, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_sane(arch):
    """FULL configs are exercised via the dry-run; here we only check the
    analytic parameter count lands near the advertised size."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "qwen3-32b": 32e9, "minitron-4b": 4e9, "qwen3-14b": 14e9,
        "granite-34b": 34e9, "whisper-large-v3": 1.55e9,
        "qwen2-vl-72b": 72e9, "grok-1-314b": 314e9,
        "granite-moe-3b-a800m": 3.3e9, "mamba2-370m": 0.37e9,
        "zamba2-2.7b": 2.7e9,
    }[arch]
    assert 0.75 * expected <= n <= 1.25 * expected, (arch, n, expected)


def test_mrope_text_degrades_to_rope():
    """M-RoPE with identical (t,h,w) ids == plain RoPE (paper 2409.12191)."""
    from repro.models import layers as L
    Dh = 32
    pos = jnp.arange(16)[None, :]
    a1 = L.rope_angles(pos, Dh, 1e4)
    pos3 = jnp.broadcast_to(pos[:, None, :], (1, 3, 16))
    a2 = L.mrope_angles(pos3, Dh, 1e4, (4, 6, 6))
    # identical ids -> every section reads the same positions
    x = jax.random.normal(KEY, (1, 16, 2, Dh))
    np.testing.assert_allclose(L.apply_rope(x, a1), L.apply_rope(x, a2),
                               atol=1e-6)


def test_moe_capacity_drops_bounded():
    cfg = get_smoke_config("granite-moe-3b-a800m")
    params = models.init(KEY, cfg)
    logits, aux = models.forward(params, _batch(cfg), cfg, RUN)
    assert float(aux["moe_drop_fraction"]) < 0.3
    assert float(aux["moe_load_balance"]) >= 0


def test_prefill_decode_consistency_dense():
    cfg = dataclasses.replace(get_smoke_config("qwen3-14b"),
                              param_dtype="float32",
                              activation_dtype="float32")
    run = dataclasses.replace(RUN, attention_impl="naive")
    params = models.init(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, 10), 0, cfg.vocab)
    full, _ = models.forward(params, {"tokens": tokens}, cfg, run)
    cache = models.init_cache(cfg, B, 32)
    outs = []
    for t in range(10):
        batch = {"tokens": tokens[:, t:t + 1],
                 "seq_lens": jnp.full((B,), t, jnp.int32)}
        lg, cache = models.decode_step(params, cache, batch, cfg, run)
        outs.append(lg)
    np.testing.assert_allclose(jnp.stack(outs, 1), full, atol=2e-4, rtol=2e-3)
