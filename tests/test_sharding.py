"""Sharding rules: divisibility safety, ZeRO specs, batch specs, roofline
parsing — plus a multi-device GSPMD equivalence test in a subprocess."""
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.configs.base import TRAIN_4K
from repro.distributed import sharding as shard
from repro.launch.presets import run_preset
from repro.train import steps


class FakeMesh:
    """Shape-only stand-in (rules never touch devices)."""

    def __init__(self, shape):
        self.shape = dict(shape)

    @property
    def devices(self):
        raise AssertionError("rules must not touch mesh devices")


MESH = FakeMesh({"data": 16, "model": 16})


def _axis_sizes(spec, shape, mesh):
    for entry, dim in zip(tuple(spec) + (None,) * (len(shape) - len(spec)),
                          shape):
        axes = entry if isinstance(entry, tuple) else \
            (entry,) if entry else ()
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        yield dim, n


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible(arch):
    """Every sharded dim must be divisible by its axis product — indivisible
    dims must be left unsharded (whisper's 20 heads etc.)."""
    cfg = get_config(arch)
    run = run_preset(cfg, TRAIN_4K)
    params_shape = steps.abstract_params(cfg)
    specs = shard.param_specs(params_shape, cfg, run, MESH)
    leaves = jax.tree.leaves(params_shape)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        for dim, n in _axis_sizes(spec, leaf.shape, MESH):
            assert dim % n == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["qwen3-32b", "grok-1-314b", "mamba2-370m"])
def test_opt_specs_zero1(arch):
    """m/v must be sharded at least as much as params (ZeRO-1 adds 'data')."""
    cfg = get_config(arch)
    run = run_preset(cfg, TRAIN_4K)
    params_shape, opt_shape, pspecs, ospecs = steps.train_shardings(
        cfg, run, MESH)
    m_specs = jax.tree.leaves(ospecs["m"], is_leaf=lambda s: isinstance(s, P))
    p_specs = jax.tree.leaves(pspecs, is_leaf=lambda s: isinstance(s, P))
    p_leaves = jax.tree.leaves(params_shape)
    for pl, ps, ms in zip(p_leaves, p_specs, m_specs):
        def n_shards(spec):
            total = 1
            for _, n in _axis_sizes(spec, pl.shape, MESH):
                total *= n
            return total
        assert n_shards(ms) >= n_shards(ps), (arch, pl.shape, ps, ms)
        for dim, n in _axis_sizes(ms, pl.shape, MESH):
            assert dim % n == 0


def test_whisper_heads_not_tensor_sharded():
    cfg = get_config("whisper-large-v3")  # 20 heads % 16 != 0
    run = run_preset(cfg, TRAIN_4K)
    params_shape = steps.abstract_params(cfg)
    specs = shard.param_specs(params_shape, cfg, run, MESH)
    wq_spec = specs["decoder"]["attn"]["wq"]
    assert "model" not in jax.tree.leaves(
        [list(wq_spec)], is_leaf=lambda x: True) or \
        wq_spec[-1] != "model"
    # but its MLP IS tensor-parallel (5120 % 16 == 0)
    assert specs["decoder"]["mlp"]["w_up"][-1] == "model"


def test_batch_spec_for():
    assert shard.batch_spec_for(MESH, 256, 1) == P(("data",), None)
    assert shard.batch_spec_for(MESH, 1, 1) == P(None, None)  # indivisible
    pod_mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert shard.batch_spec_for(pod_mesh, 256, 0) == P(("pod", "data"))
    assert shard.batch_spec_for(pod_mesh, 16, 0) == P(("pod",))  # partial


def test_hlo_cost_walker_known_case():
    """Loop-aware flops: a 10-step scanned matmul == its unrolled form."""
    import jax.numpy as jnp
    from repro.roofline.hlo_cost import analyze_hlo

    def scanned(x, w):
        def b(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(b, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    t = analyze_hlo(jax.jit(scanned).lower(x, x).compile().as_text())
    assert abs(t.flops - 10 * 2 * 256 ** 3) / (10 * 2 * 256 ** 3) < 0.01


@pytest.mark.slow
def test_multi_device_train_step_matches_single(tmp_path):
    """GSPMD equivalence: the sharded (2,2)-mesh train step computes the
    same loss as single-device — run in a subprocess with 4 host devices."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.configs.base import RunConfig
        from repro import models
        from repro.train import optimizer as opt, steps

        cfg = get_smoke_config("qwen3-14b")
        run = RunConfig(attention_impl="chunked", attention_chunk=16,
                        remat="full", microbatches=2)
        key = jax.random.PRNGKey(0)
        params = models.init(key, cfg)
        opt_state = opt.init_opt_state(params, run)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab)}

        # single device
        f1 = jax.jit(steps.make_train_step(cfg, run))
        _, _, m1 = f1(params, opt_state, batch)

        # (2,2) mesh via the framework's sharding derivation
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        bshape = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch)
        f2, _ = steps.jit_train_step(cfg, run, mesh, bshape)
        _, _, m2 = f2(params, opt_state, batch)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) / max(abs(l1), 1e-9) < 2e-2, (l1, l2)
        print("OK", l1, l2)
    """)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=560,
                         env={**__import__('os').environ,
                              "PYTHONPATH": "src"},
                         cwd=__import__('os').path.dirname(
                             __import__('os').path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout
