"""The v2 fluent API: decorator registration + stream combinators.

Three contracts:
(a) a topology built with decorators/combinators compiles to the *same*
    Application spec graph as the v1 spec-style build (modulo logic callables);
(b) combinator payloads (.map/.filter/.fuse/.window) flow end-to-end on a
    live Operator;
(c) schema inference rejects, at composition time, a combinator whose output
    violates the declared downstream schema.
"""
import dataclasses
import time

import pytest

from repro.core import (ActuatorSpec, AnalyticsUnitSpec, App, Application,
                        ConfigSchema, DriverSpec, DSLError, FieldSpec,
                        GadgetSpec, Operator, SchemaMismatch, SensorSpec,
                        StreamHandle, StreamSchema, StreamSpec, connect,
                        drain)

READING = StreamSchema.of(t=FieldSpec("float"))
SCORE = StreamSchema.of(t=FieldSpec("float"), score=FieldSpec("float"))


# ---------------------------------------------------------------------------
# Shared business logic (identical callables for v1 and v2 builds)
# ---------------------------------------------------------------------------

def _thermometer_gen(n):
    return ({"t": 20.0 + i} for i in range(n))


def _scorer(ctx):
    return lambda s, p: {"t": p["t"], "score": p["t"] - 20.0}


def _quickstart_v2() -> App:
    """The examples/quickstart.py topology, v2 style."""
    app = App("quickstart")

    @app.driver(emits=READING, name="thermometer")
    def thermometer(ctx, n=200):
        return _thermometer_gen(n)

    @app.analytics_unit(expects=(READING,), emits=SCORE, name="anomaly")
    def anomaly(ctx):
        return _scorer(ctx)

    @app.actuator(expects=(SCORE,), name="alarm")
    def alarm(ctx, threshold=4.0):
        return lambda s, p: None

    scores = app.sense("lab-temp", thermometer, n=200).via(anomaly,
                                                           name="anomalies")
    scores >> app.gadget("siren", alarm)
    return app


def _quickstart_v1() -> Application:
    """The same topology, v1 spec-style (what v2 must compile down to)."""
    app = Application(name="quickstart")
    app.driver(DriverSpec(
        name="thermometer", logic=lambda ctx: _thermometer_gen(ctx.config["n"]),
        config_schema=ConfigSchema.of(n=("int", 200)), output_schema=READING))
    app.analytics_unit(AnalyticsUnitSpec(
        name="anomaly", logic=_scorer, input_schemas=(READING,),
        output_schema=SCORE))
    app.actuator(ActuatorSpec(
        name="alarm", logic=lambda ctx: (lambda s, p: None),
        config_schema=ConfigSchema.of(threshold=("float", 4.0)),
        input_schemas=(SCORE,)))
    app.sensor(SensorSpec(name="lab-temp", driver="thermometer",
                          config={"n": 200}))
    app.stream(StreamSpec(name="anomalies", analytics_unit="anomaly",
                          inputs=("lab-temp",)))
    app.gadget(GadgetSpec(name="siren", actuator="alarm",
                          inputs=("anomalies",)))
    return app


def _comparable(a: Application) -> dict:
    """Project an Application to its logic-free spec graph."""
    def proj(spec):
        d = dataclasses.asdict(spec)
        d.pop("logic", None)
        return d
    return {field: [proj(s) for s in getattr(a, field)]
            for field in ("drivers", "analytics_units", "actuators",
                          "sensors", "streams", "gadgets", "databases")}


# ---------------------------------------------------------------------------
# (a) compile equivalence
# ---------------------------------------------------------------------------

def test_v2_compiles_to_v1_spec_graph():
    v1, v2 = _quickstart_v1(), _quickstart_v2().build()
    assert _comparable(v1) == _comparable(v2)
    # both graphs validate to the same topo order
    assert v1.validate() == v2.validate() == ["anomalies"]
    assert v1.loc_footprint() == v2.loc_footprint() == 6


def test_config_schema_inferred_from_keyword_defaults():
    app = App("infer")

    @app.driver
    def src(ctx, rate=2.5, url: str = "nats://x", verbose=False, n=3):
        return iter(())

    schema = app.build().drivers[0].config_schema
    assert schema.fields == {"rate": ("float", 2.5), "url": ("str", "nats://x"),
                             "verbose": ("bool", False), "n": ("int", 3)}
    # a parameter without a default compiles to a REQUIRED field
    @app.analytics_unit
    def au(ctx, mode: str):
        return lambda s, p: p

    au_schema = app.build().analytics_units[0].config_schema
    assert au_schema.fields == {"mode": ("str", ConfigSchema.REQUIRED)}
    with pytest.raises(KeyError):
        au_schema.validate({})


def test_output_schema_from_return_annotation():
    app = App("ann")

    @app.driver
    def src(ctx) -> READING:  # type: ignore[valid-type]
        return iter(())

    assert app.build().drivers[0].output_schema == READING


def test_output_schema_from_stringified_annotation():
    """PEP 563 (`from __future__ import annotations`) stringifies return
    annotations; inference must resolve them against the factory's globals."""
    app = App("ann-str")

    @app.driver
    def src(ctx) -> "READING":  # what PEP 563 turns `-> READING` into
        return iter(())

    @app.driver
    def unresolvable(ctx) -> "NOT_A_NAME":  # noqa: F821
        return iter(())

    built = app.build()
    assert built.drivers[0].output_schema == READING
    assert built.drivers[1].output_schema == StreamSchema.untyped()


def test_duplicate_names_rejected():
    app = App("dups")

    @app.driver(emits=READING)
    def src(ctx):
        return iter(())

    with pytest.raises(DSLError):
        @app.driver(name="src")
        def src2(ctx):
            return iter(())

    app.sense("s", src)
    with pytest.raises(DSLError):
        app.sense("s", src)


# ---------------------------------------------------------------------------
# (b) combinators flow end-to-end on a live Operator
# ---------------------------------------------------------------------------

def test_map_filter_fuse_window_end_to_end():
    app = App("combo")

    @app.driver(emits=READING)
    def src(ctx, n=10):
        return iter([{"t": float(i)} for i in range(n)])

    raw = app.sense("raw", src)
    doubled = raw.map(lambda p: {"t": p["t"] * 2}, emits=READING,
                      name="doubled")
    big = doubled.filter(lambda p: p["t"] >= 10.0, name="big")
    big.window(2, name="pairs")
    StreamHandle.fuse(
        doubled, big, with_=lambda a, b: {"t": a["t"] + b["t"]},
        emits=READING, name="summed")

    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        sub_pairs = op.subscribe("pairs")
        sub_sum = op.subscribe("summed")
        sub_big = op.subscribe("big")
        op.start_pending_sensors()
        # doubled = 0,2,...,18 ; big = 10,...,18 (5 msgs)
        assert [m.payload["t"] for m in drain(sub_big, 5)] == \
            [10.0, 12.0, 14.0, 16.0, 18.0]
        # tumbling window of 2 over big -> 2 full windows
        wins = drain(sub_pairs, 2)
        assert [m.payload["count"] for m in wins] == [2, 2]
        assert [p["t"] for p in wins[0].payload["window"]] == [10.0, 12.0]
        # FIFO pairing of doubled with big
        assert [m.payload["t"] for m in drain(sub_sum, 3)] == \
            [10.0, 14.0, 18.0]


def test_via_decorated_au_and_gadget_sink_live():
    app = App("live")
    hits: list[dict] = []

    @app.driver(emits=READING)
    def src(ctx, n=5):
        return iter([{"t": 20.0 + i} for i in range(n)])

    @app.analytics_unit(expects=(READING,), emits=SCORE)
    def scorer(ctx):
        return _scorer(ctx)

    @app.actuator(expects=(SCORE,))
    def sink(ctx, threshold=2.0):
        return lambda s, p: hits.append(p) if p["score"] > threshold else None

    app.sense("in", src).via(scorer, name="scores") >> app.gadget("g", sink)
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        sub = op.subscribe("scores")
        op.start_pending_sensors()
        assert len(drain(sub, 5)) == 5
        deadline = time.monotonic() + 5
        while len(hits) < 2 and time.monotonic() < deadline:
            time.sleep(0.02)
    assert sorted(p["score"] for p in hits) == [3.0, 4.0]


def test_synthetic_aus_are_observable_entities():
    """Combinator lambdas become real (upgradeable/observable) AU specs."""
    app = App("syn")

    @app.driver(emits=READING)
    def src(ctx):
        return iter(())

    app.sense("s", src).map(lambda p: p, name="s2")
    built = app.build()
    assert [a.name for a in built.analytics_units] == ["s2.map"]
    spec = built.analytics_units[0]
    assert (spec.min_instances, spec.max_instances) == (1, 1)
    assert built.streams[0].fixed_instances == 1
    assert app.declared_footprint() == app.loc_footprint() - 1


# ---------------------------------------------------------------------------
# (c) eager schema rejection at composition time
# ---------------------------------------------------------------------------

def test_map_output_violating_downstream_schema_rejected():
    app = App("reject")

    @app.driver(emits=READING)
    def src(ctx):
        return iter(())

    @app.analytics_unit(expects=(SCORE,), emits=SCORE)
    def needs_scores(ctx):
        return lambda s, p: p

    raw = app.sense("s", src)
    # READING lacks the required 'score' field demanded by the AU
    with pytest.raises(SchemaMismatch):
        raw.map(lambda p: p, emits=READING, name="still-readings") \
           .via(needs_scores)
    # an untyped map makes no guarantees -> also rejected by a typed consumer
    with pytest.raises(SchemaMismatch):
        raw.map(lambda p: p, name="untyped").via(needs_scores)


def test_gadget_edge_schema_rejected():
    app = App("reject-gadget")

    @app.driver(emits=READING)
    def src(ctx):
        return iter(())

    @app.actuator(expects=(SCORE,))
    def sink(ctx):
        return lambda s, p: None

    with pytest.raises(SchemaMismatch):
        app.sense("s", src) >> app.gadget("g", sink)


def test_sense_validates_config_eagerly():
    app = App("cfg")

    @app.driver(emits=READING)
    def src(ctx, n=5):
        return iter(())

    with pytest.raises(KeyError):
        app.sense("s", src, bogus=1)
    with pytest.raises(TypeError):
        app.sense("s", src, n="not-an-int")


def test_fuse_requires_two_streams_same_app():
    app_a, app_b = App("a"), App("b")

    @app_a.driver(emits=READING)
    def src_a(ctx):
        return iter(())

    @app_b.driver(emits=READING)
    def src_b(ctx):
        return iter(())

    ha, hb = app_a.sense("sa", src_a), app_b.sense("sb", src_b)
    with pytest.raises(DSLError):
        StreamHandle.fuse(ha, with_=lambda a: a)
    with pytest.raises(DSLError):
        StreamHandle.fuse(ha, hb, with_=lambda a, b: a)
    # a self-join would collapse the per-stream pairing buffers — rejected
    with pytest.raises(DSLError):
        StreamHandle.fuse(ha, ha, with_=lambda a, b: a)


def test_fuse_rejects_misdirected_kwargs():
    app = App("fuse-kwargs")

    @app.driver(emits=READING)
    def src(ctx):
        return iter(())

    @app.analytics_unit(expects=(READING, READING), emits=READING)
    def joiner(ctx):
        return lambda s, p: p

    ha, hb = app.sense("a", src), app.sense("b", src)
    # config kwargs can't reach a plain callable — loud, not silent
    with pytest.raises(DSLError):
        StreamHandle.fuse(ha, hb, with_=lambda x, y: x, gain=2.0)
    # a callable fuse's pairing buffer is per-instance: single-instance only
    with pytest.raises(DSLError):
        StreamHandle.fuse(ha, hb, with_=lambda x, y: x, fixed_instances=2)
    # a registered AU's output schema is declared, not overridden by emits=
    with pytest.raises(DSLError):
        StreamHandle.fuse(ha, hb, with_=joiner, emits=SCORE)


def test_duplicate_database_rejected_at_declaration():
    app = App("dbs")
    app.database("x")
    with pytest.raises(DSLError):
        app.database("x")


# ---------------------------------------------------------------------------
# .via(upgrade=...) — §4 config upgrades through the DSL
# ---------------------------------------------------------------------------

def _deploy_v1_scorer(op):
    app1 = App("team-a")

    @app1.driver(emits=READING, name="src")
    def src(ctx, n=6):
        return iter([{"t": float(i)} for i in range(n)])

    @app1.analytics_unit(expects=(READING,), emits=SCORE, name="scorer")
    def scorer(ctx):
        return lambda s, p: {"t": p["t"], "score": p["t"]}

    app1.sense("raw", src).via(scorer, name="scores")
    app1.deploy(op, start_sensors=False)


def test_via_upgrade_recomposes_to_operator_upgrade():
    with connect(start=False) as op:
        _deploy_v1_scorer(op)

        app2 = App("team-b")

        @app2.analytics_unit(expects=(READING,), emits=SCORE, name="scorer",
                             version=2)
        def scorer2(ctx, gain=2.0):
            return lambda s, p: {"t": p["t"], "score": p["t"] * gain}

        app2.external("raw", READING).via(scorer2, name="scores2",
                                          upgrade=True, gain=3.0)
        app2.deploy(op, start_sensors=False)
        # the running AU was upgraded in place (cascade), not re-registered
        assert op.describe()["analytics_units"]["scorer"] == 2
        assert any(e[1] == "upgrade" for e in op.events)
        sub_old = op.subscribe("scores")
        sub_new = op.subscribe("scores2")
        op.start_pending_sensors()
        # the pre-existing stream now runs v2 logic (default gain=2.0) ...
        assert [m.payload["score"] for m in drain(sub_old, 6)] == \
            [0.0, 2.0, 4.0, 6.0, 8.0, 10.0]
        # ... and the new stream uses its wiring-line config (gain=3.0)
        assert [m.payload["score"] for m in drain(sub_new, 6)] == \
            [0.0, 3.0, 6.0, 9.0, 12.0, 15.0]


def test_via_upgrade_with_converter():
    with connect(start=False) as op:
        _deploy_v1_scorer(op)

        app2 = App("team-b")

        @app2.analytics_unit(expects=(READING,), emits=SCORE, name="scorer",
                             version=2)
        def scorer2(ctx, gain: float):      # new REQUIRED field: incompatible
            return lambda s, p: {"t": p["t"], "score": p["t"] * gain}

        app2.external("raw", READING).via(
            scorer2, name="scores2", gain=3.0,
            upgrade=lambda cfg: {**cfg, "gain": 2.0})
        app2.deploy(op, start_sensors=False)
        assert op.describe()["analytics_units"]["scorer"] == 2


def test_via_without_upgrade_still_refuses_redeclared_au():
    from repro.core import OperatorError
    with connect(start=False) as op:
        _deploy_v1_scorer(op)

        app2 = App("team-b")

        @app2.analytics_unit(expects=(READING,), emits=SCORE, name="scorer",
                             version=2)
        def scorer2(ctx):
            return lambda s, p: p

        app2.external("raw", READING).via(scorer2, name="scores2")
        with pytest.raises(OperatorError):
            app2.deploy(op, start_sensors=False)


# ---------------------------------------------------------------------------
# connect() lifecycle
# ---------------------------------------------------------------------------

def test_connect_owns_operator_lifecycle():
    with connect(reconcile_interval_s=0.05) as op:
        assert isinstance(op, Operator)
        assert op._reconciler is not None and op._reconciler.is_alive()
        bus = op.bus
    assert op._reconciler is None          # reconciler joined on exit
    with pytest.raises(Exception):
        bus.publish("x", {}, token="t")    # bus closed
