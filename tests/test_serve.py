"""Serving: continuous batching correctness + slot reuse + persistence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.core.state import StateStore
from repro.serve import CacheFullError, ServeEngine, SlotAllocator
from repro.serve.batcher import ContinuousBatcher, Request

RUN = RunConfig(attention_impl="naive", remat="none", attention_chunk=16)
KEY = jax.random.PRNGKey(0)


def _f32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32",
                               activation_dtype="float32")


@pytest.mark.parametrize("arch", ["qwen3-32b", "mamba2-370m", "zamba2-2.7b",
                                  "grok-1-314b", "whisper-large-v3"])
def test_engine_matches_full_forward_greedy(arch):
    """All five families: continuous batching (ragged joins, slot reuse)
    must emit exactly the greedy continuation of a full forward pass."""
    cfg = _f32(get_smoke_config(arch))
    params = models.init(KEY, cfg)
    eng = ServeEngine(cfg, RUN, params, n_slots=2, max_seq=64)
    prompts = {f"r{i}": list(np.random.default_rng(i).integers(
        1, cfg.vocab, 4 + 2 * i)) for i in range(3)}
    for rid, p in prompts.items():
        eng.submit(rid, p, max_new_tokens=5)
    done = eng.run_until_idle()
    assert len(done) == 3

    def fwd_batch(toks):
        b = {"tokens": jnp.asarray([toks])}
        if cfg.family == "encdec":
            b["frames"] = jnp.zeros((1, cfg.encoder_seq, cfg.d_model))
        return b

    def ref_next_full_forward(toks):
        logits, _ = models.forward(params, fwd_batch(toks), cfg, RUN)
        return int(jnp.argmax(logits[0, -1]))

    def ref_next_decode(state, toks):
        """Token-by-token decode reference (B=1) — required for MoE:
        capacity-based routing is group-size dependent, so a full forward
        (one group of len(toks) tokens, drops possible) legitimately
        differs from decode (one token, never drops).  This is the
        standard capacity-MoE train/inference routing gap, not an engine
        bug; the decode reference shares the engine's routing regime."""
        cache, pos = state
        lg = None
        while pos < len(toks):
            batch = {"tokens": jnp.asarray([[toks[pos]]]),
                     "seq_lens": jnp.asarray([pos], jnp.int32)}
            lg, cache = models.decode_step(params, cache, batch, cfg, RUN)
            pos += 1
        state[0], state[1] = cache, pos
        return int(jnp.argmax(lg[0]))

    for rid, prompt in prompts.items():
        gen = next(r for r in done if r.request_id == rid).generated
        toks = list(prompt)
        dec_state = [models.init_cache(cfg, 1, 64), 0]
        for step in range(5):
            if cfg.family == "moe":
                nxt = ref_next_decode(dec_state, toks)
            else:
                nxt = ref_next_full_forward(toks)
            assert gen[step] == nxt, (rid, step, gen)
            toks.append(nxt)


def test_slot_reuse_continuous_batching():
    cfg = _f32(get_smoke_config("qwen3-32b"))
    params = models.init(KEY, cfg)
    eng = ServeEngine(cfg, RUN, params, n_slots=2, max_seq=32)
    for i in range(5):  # 5 requests through 2 slots
        eng.submit(f"r{i}", [1 + i, 2, 3], max_new_tokens=3)
    done = eng.run_until_idle()
    assert len(done) == 5
    assert eng.slots.n_free == 2            # all slots returned
    assert all(len(r.generated) == 3 for r in done)


def test_slot_allocator_exhaustion_and_persistence():
    store = StateStore()
    db = store.create("serving")
    alloc = SlotAllocator(2, db=db)
    alloc.alloc("a")
    alloc.alloc("b")
    with pytest.raises(CacheFullError):
        alloc.alloc("c")
    alloc.free("a")
    alloc.alloc("c")
    # restart: session map recovered from the platform database
    alloc2 = SlotAllocator(2, db=db)
    assert alloc2.n_free == 0
    assert alloc2.slot_of("b") is not None and alloc2.slot_of("c") is not None


def test_batcher_policy():
    b = ContinuousBatcher(n_slots=2, max_prefill_per_tick=1)
    for i in range(3):
        b.submit(Request(request_id=i, prompt=[1], max_new_tokens=1))
    t1 = b.plan_tick(free_slots=2)
    assert len(t1.admit) == 1 and not t1.decode
    t1.admit[0].prefill_done = True
    t1.admit[0].generated = [5]             # done (max_new_tokens=1)
    t2 = b.plan_tick(free_slots=1)
    assert t1.admit[0] in t2.finished or len(t2.admit) == 1
    assert len(b.completed) >= 1
