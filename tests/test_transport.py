"""Cross-host transport: BusServer/RemoteBus semantics over real TCP.

The in-process tests drive client and server through loopback sockets inside
one interpreter (fast, deterministic); the acceptance test at the bottom
spawns REAL worker processes via ``benchmarks/transport_worker.py`` and kills
one mid-stream, asserting the ISSUE's zero-loss / zero-double-delivery /
zero-ordering-violation bar across the re-home.
"""
from __future__ import annotations

import pathlib
import socket
import struct
import sys
import tempfile
import time

import numpy as np
import pytest

from repro.core import (FieldSpec, MessageBus, Operator, RemoteWorker,
                        Sidecar, StreamSchema, Unauthorized, UnknownSubject,
                        connect)
from repro.core.dsl import DSLError
from repro.core.sdk import sdk_entrypoint
from repro.core.transport import (MAX_FRAME_BYTES, PROTO_VERSION, BusServer,
                                  RemoteBus, TransportError, pack_frame,
                                  read_frame, unpack_frame)

_REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO))  # for the benchmarks.* helpers
from benchmarks.bench_transport import (await_members, ordering_violations,
                                        read_records, spawn_worker,
                                        wait_for)  # noqa: E402

SCHEMA = StreamSchema.of(k=FieldSpec("str"), i=FieldSpec("int"))


def _served_bus(**server_kw):
    bus = MessageBus()
    bus.register_subject("t", SCHEMA)
    server = BusServer(bus, **server_kw)
    tok = bus.issue_token("pub", ["t"])
    return bus, server, tok


def _drain(sub, n, timeout=5.0):
    got, deadline = [], time.monotonic() + timeout
    while len(got) < n and time.monotonic() < deadline:
        got.extend(sub.next_batch(n - len(got), timeout=0.1))
    return got


# ---------------------------------------------------------------------------
# Frames
# ---------------------------------------------------------------------------

class TestFrames:
    def test_roundtrip_with_numpy(self):
        frame = {"op": "msg", "x": np.arange(6, dtype=np.float32),
                 "nested": {"b": b"\x00\xff"}}
        data = pack_frame(frame)
        (length,) = struct.unpack(">I", data[:4])
        assert length == len(data) - 4
        out = unpack_frame(data[4:])
        assert out["op"] == "msg"
        np.testing.assert_array_equal(out["x"], frame["x"])
        assert out["nested"]["b"] == b"\x00\xff"

    def test_oversize_frame_refused(self):
        a, b = socket.socketpair()
        try:
            a.sendall(struct.pack(">I", MAX_FRAME_BYTES + 1))
            with pytest.raises(TransportError):
                read_frame(b)
        finally:
            a.close()
            b.close()


# ---------------------------------------------------------------------------
# Handshake / RPC surface
# ---------------------------------------------------------------------------

class TestHandshake:
    def test_hello_carries_subjects(self):
        bus, server, _ = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="c1")
            assert rb.subjects_cache == ["t"]
            assert rb.subjects() == ["t"]
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_protocol_mismatch_rejected(self):
        bus, server, _ = _served_bus()
        try:
            sock = socket.create_connection(server.address, timeout=5)
            sock.sendall(pack_frame({"op": "hello", "rid": 0, "proto": 99}))
            reply, _, _ = read_frame(sock)
            assert reply["ok"] is False
            assert reply["kind"] == "TransportError"
            sock.close()
        finally:
            server.close()
            bus.close()

    def test_connect_refused_after_backoff(self):
        with socket.socket() as probe:
            probe.bind(("127.0.0.1", 0))
            free_port = probe.getsockname()[1]
        t0 = time.monotonic()
        with pytest.raises(TransportError):
            RemoteBus(("127.0.0.1", free_port), connect_timeout=0.5)
        assert time.monotonic() - t0 >= 0.4  # it retried, not failed fast

    def test_errors_map_to_bus_exceptions(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address)
            with pytest.raises(UnknownSubject):
                rb.publish("nope", {"k": "a", "i": 0}, token=tok)
            with pytest.raises(Unauthorized):
                rb.publish("t", {"k": "a", "i": 0}, token="bad-token")
            bad_tok = rb.issue_token("x", ["t"])
            with pytest.raises(Exception):  # schema violation -> BusError
                rb.publish("t", {"k": "a", "i": "not-an-int"}, token=bad_tok)
            rb.close()
        finally:
            server.close()
            bus.close()


# ---------------------------------------------------------------------------
# Delivery policies across the wire
# ---------------------------------------------------------------------------

class TestRemoteDelivery:
    def test_remote_and_local_members_share_one_group(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="w")
            local = bus.subscribe("t", token=tok, group="g", name="local")
            remote = rb.subscribe("t", token=rb.issue_token("w", ["t"]),
                                  group="g", name="remote")
            info = bus.group_info("t", "g")
            assert sorted(info["members"]) == ["local", "remote"]
            for i in range(40):
                rb.publish("t", {"k": "a", "i": i}, token=tok)
            got_r = _drain(remote, 40, timeout=3.0)
            got_l = []
            while True:
                m = local.next(timeout=0.1)
                if m is None and len(got_l) + len(got_r) >= 40:
                    break
                if m is not None:
                    got_l.append(m)
            assert len(got_l) + len(got_r) == 40
            assert got_l and got_r  # both actually shared the work
            assert sorted(m.payload["i"] for m in got_l + got_r) == list(range(40))
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_keyed_remote_members_sticky_per_key(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="w")
            wtok = rb.issue_token("w", ["t"])
            subs = [rb.subscribe("t", token=wtok, group="kg", key="k",
                                 name=f"m{i}") for i in range(2)]
            info = bus.group_info("t", "kg")
            assert info["policy"] == "keyed"
            assert set(info["assignment"].values()) <= {"m0", "m1"}
            for i in range(60):
                rb.publish("t", {"k": f"key-{i % 6}", "i": i}, token=tok)
            got = {s.name: _drain(s, 60, timeout=2.0) for s in subs}
            assert sum(len(v) for v in got.values()) == 60
            # stickiness: each key consumed by exactly one member
            owners = {}
            for name, msgs in got.items():
                for m in msgs:
                    assert owners.setdefault(m.payload["k"], name) == name
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_clean_unsubscribe_rehomes_unacked_backlog_in_order(self):
        bus, server, tok = _served_bus()
        try:
            rb1 = RemoteBus(server.address, peer="w1")
            rb2 = RemoteBus(server.address, peer="w2")
            s1 = rb1.subscribe("t", token=rb1.issue_token("w1", ["t"]),
                               group="kg", key="k", name="w1", auto_ack=False)
            s2 = rb2.subscribe("t", token=rb2.issue_token("w2", ["t"]),
                               group="kg", key="k", name="w2", auto_ack=False)
            for i in range(30):
                rb1.publish("t", {"k": f"key-{i % 4}", "i": i}, token=tok)
            # pop (but never ack) whatever reached w1, then leave cleanly:
            # everything w1 held — popped or still queued — must re-home
            time.sleep(0.3)
            popped = s1.next_batch(30, timeout=0.5)
            rb1.unsubscribe(s1)
            seen2 = _drain(s2, 30, timeout=5.0)
            s2.ack(len(seen2))
            assert sorted(m.payload["i"] for m in seen2) == list(range(30))
            # per-key order survived the hand-off
            last: dict[str, int] = {}
            for m in seen2:
                assert m.payload["i"] > last.get(m.payload["k"], -1)
                last[m.payload["k"]] = m.payload["i"]
            assert popped is not None  # w1 really had taken some first
            rb1.close()
            rb2.close()
        finally:
            server.close()
            bus.close()

    def test_replay_over_the_wire(self):
        bus = MessageBus()
        bus.register_subject("t", SCHEMA)
        bus.make_durable("t")
        server = BusServer(bus)
        tok = bus.issue_token("pub", ["t"])
        try:
            for i in range(10):
                bus.publish("t", {"k": "a", "i": i}, token=tok)
            rb = RemoteBus(server.address, peer="late")
            log = rb.durable_log("t")
            assert log is not None and log.info()["depth"] == 10
            sub = rb.subscribe("t", token=rb.issue_token("late", ["t"]),
                               name="late", replay_from="earliest")
            history = _drain(sub, 10, timeout=5.0)
            assert [m.payload["i"] for m in history] == list(range(10))
            assert [m.headers["offset"] for m in history] == list(range(10))
            live = rb.publish("t", {"k": "a", "i": 10}, token=tok)
            assert live.headers["offset"] == 10
            tail = _drain(sub, 1, timeout=5.0)
            assert tail and tail[0].payload["i"] == 10
            rb.close()
        finally:
            server.close()
            bus.close()


# ---------------------------------------------------------------------------
# Liveness: crashes, heartbeats, reconnects
# ---------------------------------------------------------------------------

class TestLiveness:
    def test_dropped_connection_requeues_unacked_to_survivor(self):
        bus, server, tok = _served_bus()
        try:
            rb1 = RemoteBus(server.address, peer="w1")
            rb2 = RemoteBus(server.address, peer="w2")
            s1 = rb1.subscribe("t", token=rb1.issue_token("w1", ["t"]),
                               group="g", name="w1", auto_ack=False)
            s2 = rb2.subscribe("t", token=rb2.issue_token("w2", ["t"]),
                               group="g", name="w2", auto_ack=False)
            for i in range(20):
                rb2.publish("t", {"k": "a", "i": i}, token=tok)
            time.sleep(0.3)  # let deliveries spread over both members
            # simulate a crash: the socket dies with no goodbye and nothing
            # acked — the server must re-home ALL of w1's share
            rb1._drop_connection("simulated crash")
            got = _drain(s2, 20, timeout=5.0)
            s2.ack(len(got))
            assert sorted(m.payload["i"] for m in got) == list(range(20))
            assert s1.closed  # the dropped client's consumer unblocked
            rb2.close()
            rb1.close()
        finally:
            server.close()
            bus.close()

    def test_silent_peer_is_reaped_not_hung(self):
        bus, server, tok = _served_bus(hb_timeout=0.6)
        try:
            # hb_interval far beyond the server's patience: never pings
            rb = RemoteBus(server.address, peer="mute", hb_interval=60.0)
            sub = rb.subscribe("t", token=rb.issue_token("mute", ["t"]),
                               group="g", name="mute")
            deadline = time.monotonic() + 5.0
            while server.stats()["reaped"] == 0 and time.monotonic() < deadline:
                time.sleep(0.05)
            assert server.stats()["reaped"] == 1
            # the reap path retires the proxy (pump join + depart) just
            # after the counter bumps — wait for the departure to land
            deadline = time.monotonic() + 5.0
            while bus.group_info("t", "g") is not None \
                    and time.monotonic() < deadline:
                time.sleep(0.05)
            assert bus.group_info("t", "g") is None  # member departed
            deadline = time.monotonic() + 3.0
            while not sub.closed and time.monotonic() < deadline:
                time.sleep(0.05)
            assert sub.closed  # client side noticed, consumers unblock
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_reconnect_counts_and_restores_rpc(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="flaky")
            rb._drop_connection("blip")
            assert rb.transport_stats()["connected"] is False
            msg = rb.publish("t", {"k": "a", "i": 1}, token=tok)  # auto-reconnects
            assert msg.seq >= 0
            stats = rb.transport_stats()
            assert stats["connected"] is True
            assert stats["reconnects"] == 1
            rb.close()
        finally:
            server.close()
            bus.close()

    def test_unregister_subject_closes_remote_sub(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="w")
            sub = rb.subscribe("t", token=rb.issue_token("w", ["t"]), name="w")
            bus.unregister_subject("t")
            deadline = time.monotonic() + 5.0
            while not sub.closed and time.monotonic() < deadline:
                time.sleep(0.05)
            assert sub.closed
            rb.close()
        finally:
            server.close()
            bus.close()


# ---------------------------------------------------------------------------
# Operator / worker / sidecar integration
# ---------------------------------------------------------------------------

class TestIntegration:
    def test_sidecar_metrics_carry_transport_block(self):
        bus, server, tok = _served_bus()
        try:
            rb = RemoteBus(server.address, peer="w")
            side = Sidecar("w/inst-0", rb, inputs=("t",), output=None)
            m = side.metrics()
            assert m["transport"]["connected"] is True
            assert m["transport"]["reconnects"] == 0
            assert m["transport"]["frames_out"] > 0
            side.close()
            rb.close()
            # in-process buses expose no transport block
            local = Sidecar("l/inst-0", bus, inputs=(), output=None)
            assert local.metrics()["transport"] is None
            local.close()
        finally:
            server.close()
            bus.close()

    def test_remote_worker_runs_instances_against_served_operator(self):
        with connect(serve=True, start=False) as op:
            op.bus.register_subject("readings", SCHEMA)
            op.bus.register_subject("doubled", StreamSchema.of(
                k=FieldSpec("str"), i=FieldSpec("int")))
            host, port = op.bus_address
            tok = op.bus.issue_token("drv", ["readings"])
            out_tok = op.bus.issue_token("chk", ["doubled"])
            watcher = op.bus.subscribe("doubled", token=out_tok, name="chk")

            @sdk_entrypoint
            def double(dx):
                while dx.running:
                    got = dx.next(timeout=0.1)
                    if got is not None:
                        _, payload = got
                        dx.emit({"k": payload["k"], "i": payload["i"] * 2})

            with connect(remote=f"{host}:{port}", peer="box-b") as worker:
                assert isinstance(worker, RemoteWorker)
                worker.start_instance(
                    entity_kind="analytics_unit", entity_name="double",
                    owner="doubled", logic=double, config={},
                    inputs=("readings",), output="doubled", group="doubled")
                await_members(op.bus, "readings", "doubled", 1)
                for i in range(5):
                    op.bus.publish("readings", {"k": "a", "i": i}, token=tok)
                got = _drain(watcher, 5, timeout=5.0)
                assert sorted(m.payload["i"] for m in got) == [0, 2, 4, 6, 8]
                peers = op.transport_stats()["peers"]
                assert "box-b" in peers
                assert peers["box-b"]["subscriptions"] == 1
                wm = worker.metrics()
                assert all(v["transport"]["connected"] for v in wm.values())
        assert op.bus_address is None or True  # shutdown tore the server down

    def test_connect_remote_rejects_operator_kwargs(self):
        with pytest.raises(DSLError):
            with connect(remote="127.0.0.1:1", serve=True):
                pass

    def test_operator_serve_is_idempotent_and_torn_down(self):
        op = Operator()
        addr1 = op.serve()
        addr2 = op.serve()
        assert addr1 == addr2
        assert op.transport_stats()["peers"] == {}
        op.shutdown()
        assert op.bus_address is None


# ---------------------------------------------------------------------------
# THE acceptance test: 2-process pipeline with a forced consumer kill
# ---------------------------------------------------------------------------

class TestTwoProcessKill:
    def test_kill_mid_stream_zero_loss_zero_double_delivery(self, tmp_path):
        """Driver publishes in THIS process; two keyed consumers run in
        SEPARATE processes; one dies via os._exit after 100 acked messages.
        Every published record must appear in the union of the worker logs
        exactly once, with per-key order intact."""
        bus = MessageBus(default_queue_size=4096)
        schema = StreamSchema.of(k=FieldSpec("str"), v=FieldSpec("int"),
                                 i=FieldSpec("int"))
        bus.register_subject("ticks", schema)
        server = BusServer(bus, hb_timeout=8.0)
        tok = bus.issue_token("driver", ["ticks"])
        outs = [str(tmp_path / "k1.log"), str(tmp_path / "k2.log")]
        procs = [
            spawn_worker(server.address, "ticks", "kpool", "k1", outs[0],
                         key="k", kill_after=100),
            spawn_worker(server.address, "ticks", "kpool", "k2", outs[1],
                         key="k"),
        ]
        try:
            await_members(bus, "ticks", "kpool", 2, timeout=30.0)
            published = set()
            per_key = [0] * 8
            for n in range(800):
                j = n % 8
                k = f"key-{j}"
                bus.publish("ticks", {"k": k, "v": n, "i": per_key[j]},
                            token=tok)
                published.add((k, per_key[j]))
                per_key[j] += 1
            records = wait_for(published, outs, timeout=60.0)
            assert len(published - set(records)) == 0, "messages lost"
            assert len(records) == len(set(records)), "double delivery"
            assert set(records) == published
            assert ordering_violations(outs) == 0
            # the kill really happened and was treated as a member departure
            assert procs[0].wait(timeout=10.0) == 42
            assert server.stats()["disconnects"] >= 1
        finally:
            for p in procs:
                if p.poll() is None:
                    p.terminate()
            for p in procs:
                try:
                    p.wait(timeout=5.0)
                except Exception:
                    p.kill()
            server.close()
            bus.close()
