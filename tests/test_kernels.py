"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Sk,H,KH,Dh,bq,bk,causal", [
    (1, 64, 64, 4, 4, 32, 32, 32, True),      # MHA square
    (2, 128, 128, 8, 2, 64, 64, 64, True),    # GQA
    (1, 96, 96, 4, 1, 32, 32, 32, True),      # MQA, ragged blocks
    (2, 64, 128, 4, 2, 16, 64, 64, False),    # cross-attn (non-causal)
    (1, 200, 200, 2, 2, 64, 64, 64, True),    # non-divisible seq (padding)
])
def test_flash_attention_sweep(B, Sq, Sk, H, KH, Dh, bq, bk, causal, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (B, Sq, H, Dh), dtype)
    k = _rand(k2, (B, Sk, KH, Dh), dtype)
    v = _rand(k3, (B, Sk, KH, Dh), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KH,Dh,bs", [
    (2, 128, 8, 2, 64, 64),
    (1, 300, 4, 1, 32, 128),                  # MQA + padding
    (3, 64, 4, 4, 16, 32),                    # MHA
])
def test_decode_attention_sweep(B, S, H, KH, Dh, bs, dtype):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = _rand(k1, (B, H, Dh), dtype)
    kc = _rand(k2, (B, S, KH, Dh), dtype)
    vc = _rand(k3, (B, S, KH, Dh), dtype)
    lens = jax.random.randint(k4, (B,), 1, S + 1)
    out = ops.decode_attention(q, kc, vc, lens, block_s=bs)
    want = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,L,H,P,N,chunk,bh", [
    (1, 64, 8, 16, 16, 16, 4),
    (2, 100, 16, 32, 64, 32, 8),              # padding tail
    (1, 48, 4, 64, 128, 16, 4),               # big state
])
def test_ssd_scan_sweep(B, L, H, P, N, chunk, bh, dtype):
    ks = jax.random.split(KEY, 5)
    x = _rand(ks[0], (B, L, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, 1, N))
    Cm = jax.random.normal(ks[4], (B, L, 1, N))
    y, fs = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, block_h=bh)
    yr, fsr = ref.ssd_scan_ref(x, dt, A, Bm, Cm)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(fs, fsr, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,br", [((4, 32, 128), 16), ((100, 96), 32),
                                      ((3, 5, 7, 64), 8)])
def test_rmsnorm_sweep(shape, br, dtype):
    k1, k2 = jax.random.split(KEY)
    x = _rand(k1, shape, dtype)
    w = _rand(k2, shape[-1:], dtype)
    out = ops.rmsnorm(x, w, block_rows=br)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_matches_model_chunked_attention():
    """Kernel agrees with the model's lax.scan flash implementation too."""
    from repro.models import layers as L
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 96, 4, 32))
    k = jax.random.normal(k2, (2, 96, 2, 32))
    v = jax.random.normal(k3, (2, 96, 2, 32))
    a = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    b = L.chunked_attention(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(a, b, atol=3e-5, rtol=1e-4)
