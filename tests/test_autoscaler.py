"""Serverless autoscaling: backlog-driven scale decisions + operator loop."""
import time

from repro.core import (AnalyticsUnitSpec, AutoScaler, ConfigSchema,
                        DriverSpec, FieldSpec, Operator, ScalePolicy,
                        SensorSpec, StreamSchema, StreamSpec)

INT_SCHEMA = StreamSchema.of(value=FieldSpec("int"))


def burst_driver(ctx):
    def gen():
        for i in range(int(ctx.config.get("n", 400))):
            if not ctx.running:
                return
            yield {"value": i}
    return gen()


def slow_au(ctx):
    delay = float(ctx.config.get("delay", 0.02))

    def process(stream, payload):
        time.sleep(delay)
        return {"value": payload["value"]}
    return process


def test_scale_up_on_backlog_and_down_when_idle():
    op = Operator(reconcile_interval_s=0.05,
                  scale_policy=ScalePolicy(backlog_high=16, backlog_low=1,
                                           idle_s=0.5, cooldown_s=0.1))
    op.register_driver(DriverSpec(name="burst", logic=burst_driver,
                                  config_schema=ConfigSchema.of(n=("int", 400)),
                                  output_schema=INT_SCHEMA))
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="slow", logic=slow_au,
        config_schema=ConfigSchema.of(delay=("float", 0.02)),
        output_schema=INT_SCHEMA, min_instances=1, max_instances=6))
    op.register_sensor(SensorSpec(name="src", driver="burst",
                                  config={"n": 300}), start=False)
    op.create_stream(StreamSpec(name="out", analytics_unit="slow",
                                inputs=("src",)))
    op.start()
    op.start_pending_sensors()
    try:
        deadline = time.monotonic() + 20
        scaled_up = False
        while time.monotonic() < deadline:
            n = len(op.executor.instances_of("out"))
            if n > 1:
                scaled_up = True
                break
            time.sleep(0.05)
        assert scaled_up, f"never scaled up; events={op.events}"
        # after the burst drains, instances come back down
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            if len(op.executor.instances_of("out")) == 1 and \
                    any(e[1] == "scale-down" for e in op.events):
                break
            time.sleep(0.1)
        assert any(e[1] == "scale-up" for e in op.events)
        assert any(e[1] == "scale-down" for e in op.events)
    finally:
        op.shutdown()


def test_fixed_instances_never_scaled():
    op = Operator(reconcile_interval_s=0.05,
                  scale_policy=ScalePolicy(backlog_high=2, cooldown_s=0.05))
    op.register_driver(DriverSpec(name="burst", logic=burst_driver,
                                  config_schema=ConfigSchema.of(n=("int", 400)),
                                  output_schema=INT_SCHEMA))
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="slow", logic=slow_au,
        config_schema=ConfigSchema.of(delay=("float", 0.01)),
        output_schema=INT_SCHEMA, max_instances=8))
    op.register_sensor(SensorSpec(name="src", driver="burst",
                                  config={"n": 200}), start=False)
    op.create_stream(StreamSpec(name="out", analytics_unit="slow",
                                inputs=("src",), fixed_instances=2))
    op.start()
    op.start_pending_sensors()
    try:
        time.sleep(1.5)
        assert len(op.executor.instances_of("out")) == 2
        assert not any(e[1].startswith("scale") for e in op.events)
    finally:
        op.shutdown()


def test_policy_unit():
    scaler = AutoScaler(ScalePolicy(backlog_high=10, backlog_low=1,
                                    idle_s=0.0, cooldown_s=0.0))

    class FakeSidecar:
        def __init__(self, backlog, idle):
            self._m = {"instance": f"fake-{id(self):x}",
                       "backlog": backlog, "idle_s": idle}

        def metrics(self):
            return dict(self._m, received=0, dropped=0, published=0,
                        processed=0, errors=0, latency_ewma_s=0, uptime_s=1)

    class H:
        def __init__(self, backlog, idle=0.0):
            self.sidecar = FakeSidecar(backlog, idle)

    assert scaler.decide("s", [H(50)], 1, 8) == 2          # overload -> x2
    assert scaler.decide("s2", [H(0, 99), H(0, 99)], 1, 8) == 1  # idle -> -1
    assert scaler.decide("s3", [H(5)], 1, 8) == 1          # steady


def test_sustained_stealing_is_a_straggler_signal():
    scaler = AutoScaler(ScalePolicy(backlog_high=100, backlog_low=0,
                                    idle_s=1e9, cooldown_s=0.0,
                                    steal_streak=3))

    class FakeSidecar:
        def __init__(self):
            self.stolen = 0

        def metrics(self):
            return {"instance": f"fake-{id(self):x}", "backlog": 0,
                    "idle_s": 0.0, "received": 0, "dropped": 0,
                    "published": 0, "processed": 0, "errors": 0,
                    "latency_ewma_s": 0, "uptime_s": 1,
                    "groups": {"events": {"stolen": self.stolen}}}

    class H:
        def __init__(self, sc):
            self.sidecar = sc

    sides = [FakeSidecar(), FakeSidecar()]
    handles = [H(s) for s in sides]
    # the stolen counter must RISE across steal_streak consecutive
    # decisions before the pool grows — a burst of theft that settles is
    # rebalancing doing its job, not a straggler
    for stolen in (10, 20):
        for s in sides:
            s.stolen = stolen
        assert scaler.decide("st", handles, 1, 8) == 2   # streak building
    for s in sides:
        s.stolen = 30
    assert scaler.decide("st", handles, 1, 8) == 3       # structural -> +1
    # the scale-up reset the streak; flat counters keep the pool steady
    assert scaler.decide("st", handles, 1, 8) == 2
    # counter flat for a while, then one blip: no scale-up either
    for s in sides:
        s.stolen = 31
    assert scaler.decide("st", handles, 1, 8) == 2
