"""Typed delivery/addressing/sharding API (PR 8) and its deprecation shims.

Every legacy spelling must (a) keep behaving exactly like its typed
replacement and (b) emit ONE DeprecationWarning per call site — python's
default warning filter de-duplicates on (message, module, lineno), so a
hot loop over the same deprecated call warns once, not per message.
"""
import warnings

import pytest

from repro.core import (App, Broadcast, DeliveryPolicy, DSLError, FieldSpec,
                        Group, Keyed, Listen, MessageBus, Peer, ReplayFrom,
                        ShardSpec, StreamSchema, connect, drain)
from repro.core.delivery import policy_from_legacy, resolve_policy
from repro.core.schema import KNOWN_MESH_AXES


@pytest.fixture
def bus():
    b = MessageBus()
    b.register_subject("s", StreamSchema.of(x=FieldSpec("int"),
                                            k=FieldSpec("str")))
    return b


def _tok(bus, name="t"):
    return bus.issue_token(name, ["s"])


# ---------------------------------------------------------------------------
# Policy value types
# ---------------------------------------------------------------------------

def test_policy_values_validate():
    assert Broadcast().legacy_args() == (None, None, None)
    assert Group("pool").legacy_args() == ("pool", None, None)
    assert Keyed("pool", "k").legacy_args() == ("pool", "k", 64)
    assert Keyed("pool", "k", partitions=8).legacy_args() == ("pool", "k", 8)
    with pytest.raises(ValueError):
        Group("")
    with pytest.raises(ValueError):
        Keyed("", "k")
    with pytest.raises(ValueError):
        Keyed("pool", "")
    with pytest.raises(ValueError):
        Keyed("pool", "k", partitions=0)
    with pytest.raises(ValueError):
        Peer("")


def test_policy_from_legacy_roundtrip():
    assert policy_from_legacy(None, None) is None
    assert policy_from_legacy("pool", None) == Group("pool")
    assert policy_from_legacy("pool", "k", 8) == Keyed("pool", "k", 8)


def test_replay_from_constructors():
    assert ReplayFrom.offset(5).start == 5
    assert ReplayFrom.timestamp(1.5).start == 1.5
    assert ReplayFrom.earliest().start == "earliest"
    assert ReplayFrom.snapshot().start == "snapshot"


# ---------------------------------------------------------------------------
# subscribe(): typed == legacy, warning once per call site
# ---------------------------------------------------------------------------

def _pump(bus, tok, n=6):
    for i in range(n):
        bus.publish("s", {"x": i, "k": f"key{i % 3}"}, token=tok)


def test_group_policy_equals_legacy_kwarg(bus):
    tok = _tok(bus)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)  # typed = silent
        new = bus.subscribe("s", token=tok, policy=Group("pool"), name="a")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = bus.subscribe("s", token=tok, group="pool", name="b")
    assert [w for w in rec if w.category is DeprecationWarning]
    _pump(bus, tok)
    got = sorted(m.payload["x"] for m in drain(new, 3) + drain(old, 3))
    assert got == [0, 1, 2, 3, 4, 5]  # one pool: single delivery across both


def test_keyed_policy_equals_legacy_kwargs():
    """Same member names + partitions -> identical key assignment."""
    def receives(**sub_kwargs):
        b = MessageBus()
        b.register_subject("s", StreamSchema.of(x=FieldSpec("int"),
                                                k=FieldSpec("str")))
        tok = b.issue_token("t", ["s"])
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            s1 = b.subscribe("s", token=tok, name="m1", **sub_kwargs)
            s2 = b.subscribe("s", token=tok, name="m2", **sub_kwargs)
        for i in range(12):
            b.publish("s", {"x": i, "k": f"key{i % 5}"}, token=tok)
        return (sorted(m.payload["x"] for m in drain(s1, 1, timeout=2)),
                sorted(m.payload["x"] for m in drain(s2, 1, timeout=2)))

    typed = receives(policy=Keyed("pool", "k", partitions=16))
    legacy = receives(group="pool", key="k", partitions=16)
    assert typed == legacy


def test_legacy_subscribe_warns_once_per_call_site(bus):
    tok = _tok(bus)
    with warnings.catch_warnings(record=True) as rec:
        warnings.resetwarnings()
        warnings.simplefilter("default")
        for i in range(5):
            bus.subscribe("s", token=tok, group="pool", name=f"w{i}")
    assert len([w for w in rec if w.category is DeprecationWarning]) == 1


def test_typed_subscribe_never_warns(bus):
    tok = _tok(bus)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        bus.subscribe("s", token=tok, policy=Broadcast())
        bus.subscribe("s", token=tok, policy=Group("g1"), name="a")
        bus.subscribe("s", token=tok, policy=Keyed("g2", "k"), name="b")


def test_both_spellings_rejected(bus):
    tok = _tok(bus)
    with pytest.raises(TypeError):
        bus.subscribe("s", token=tok, policy=Group("pool"), group="pool")
    with pytest.raises(TypeError):
        resolve_policy(Keyed("g", "k"), None, "k", None)
    with pytest.raises(TypeError):
        bus.subscribe("s", token=tok, policy="pool")  # not a DeliveryPolicy


def test_policy_is_abstract():
    with pytest.raises(NotImplementedError):
        DeliveryPolicy().legacy_args()


# ---------------------------------------------------------------------------
# replay: typed == legacy on a durable subject
# ---------------------------------------------------------------------------

def _durable_bus():
    b = MessageBus()
    b.register_subject("s", StreamSchema.of(x=FieldSpec("int")))
    b.make_durable("s", retention={"max_records": 1000})
    return b


def test_replay_typed_equals_legacy():
    b = _durable_bus()
    tok = b.issue_token("t", ["s"])
    for i in range(4):
        b.publish("s", {"x": i}, token=tok)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        new = b.subscribe("s", token=tok, replay=ReplayFrom.earliest())
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = b.subscribe("s", token=tok, replay_from="earliest")
    assert [w for w in rec if w.category is DeprecationWarning]
    assert [m.payload["x"] for m in drain(new, 4)] == [0, 1, 2, 3]
    assert [m.payload["x"] for m in drain(old, 4)] == [0, 1, 2, 3]
    # the typed value under the old kwarg is tolerated silently
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        tolerated = b.subscribe("s", token=tok,
                                replay_from=ReplayFrom.offset(2))
    assert [m.payload["x"] for m in drain(tolerated, 2)] == [2, 3]
    with pytest.raises(TypeError):
        b.subscribe("s", token=tok, replay=ReplayFrom.earliest(),
                    replay_from="earliest")
    with pytest.raises(TypeError):
        b.subscribe("s", token=tok, replay="earliest")  # raw value needs kwarg


# ---------------------------------------------------------------------------
# connect(): Listen/Peer == serve=/remote=
# ---------------------------------------------------------------------------

def test_connect_listen_equals_serve():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        with connect(start=False, listen=Listen()) as op:
            host, port = op.bus_address
            assert host == "127.0.0.1" and port > 0
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        with connect(start=False, serve=True) as op:
            host, port = op.bus_address
            assert host == "127.0.0.1" and port > 0
    assert [w for w in rec if w.category is DeprecationWarning]


def test_connect_serve_port_forms():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with connect(start=False, serve=0) as op:
            assert op.bus_address[1] > 0
        with connect(start=False, serve=("127.0.0.1", 0)) as op:
            assert op.bus_address == ("127.0.0.1", op.bus_address[1])


def test_connect_peer_equals_remote():
    with connect(start=False, listen=Listen()) as host_op:
        addr = "%s:%d" % host_op.bus_address
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            with connect(peer=Peer(addr, name="edge-1")) as worker:
                assert worker is not None
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            with connect(remote=addr, peer="edge-2") as worker:
                assert worker is not None
        assert [w for w in rec if w.category is DeprecationWarning]


def test_connect_rejects_mixed_spellings():
    with pytest.raises(DSLError):
        with connect(listen=Listen(), serve=True):
            pass
    with pytest.raises(DSLError):
        with connect(peer=Peer("127.0.0.1:1"), remote="127.0.0.1:1"):
            pass
    with pytest.raises(DSLError):
        with connect(peer=Peer("127.0.0.1:1"), listen=Listen()):
            pass
    with pytest.raises(DSLError):
        with connect(start=False, listen="not-a-listen"):
            pass


# ---------------------------------------------------------------------------
# ShardSpec: typed == bare tuple (deprecated), axis validation at build
# ---------------------------------------------------------------------------

def test_shardspec_replaces_bare_tuple():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        spec = FieldSpec(kind="device", shape=(8, 4), dtype="float32",
                         sharding=ShardSpec(("data", None)))
    assert tuple(spec.sharding) == ("data", None)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy = FieldSpec(kind="device", shape=(8, 4), dtype="float32",
                           sharding=("data", None))
    assert [w for w in rec if w.category is DeprecationWarning]
    assert legacy.sharding == spec.sharding  # coerced to the same ShardSpec
    assert isinstance(legacy.sharding, ShardSpec)
    with pytest.raises(ValueError):
        ShardSpec(("data", 3))  # entries are axis names or None
    with pytest.raises(ValueError):
        FieldSpec(kind="device", shape=(8,), dtype="float32", sharding=42)


def test_shardspec_axis_validation():
    spec = ShardSpec(("data", None))
    spec.validate_axes({"data", "model"})
    with pytest.raises(ValueError):
        ShardSpec(("bogus",)).validate_axes(set(KNOWN_MESH_AXES))


def test_build_rejects_unknown_mesh_axis():
    app = App("shard-check")
    bad = StreamSchema.device(x=((4, 4), "float32", ShardSpec(("bogus", None))))

    @app.driver(emits=bad)
    def src(ctx):
        return iter(())

    app.sense("frames", src)
    with pytest.raises(DSLError):
        app.build()


def test_build_accepts_known_mesh_axes():
    app = App("shard-ok")
    good = StreamSchema.device(x=((4, 4), "float32", ShardSpec(("data", None))))

    @app.driver(emits=good)
    def src(ctx):
        return iter(())

    app.sense("frames", src)
    app.build()  # no error
