"""Durable streams (tentpole PR 6): append-only subject logs with replay,
retention, and exactly-once keyed recovery.

Log level: ``DurableLog`` appends codec-tagged compressed records into
rolling segments, enforces retention by count/age/bytes (whole sealed
segments), persists a catalog + segments + trained dictionary under a root
directory, and serves offset/timestamp/earliest reads.

Bus level: ``make_durable`` attaches a log to a subject; ``publish`` appends
BEFORE delivery and stamps ``headers["offset"]``; ``subscribe(replay_from=)``
serves history first and flips to live with no gap and no duplicate; a
replaying member of a round-robin group is not picked for live delivery
until caught up (the group-guard regression).

Recovery level: ``KeyedStore.apply_once`` + snapshot watermarks +
``resolve_replay_from("snapshot")`` give keyed stateful stages exactly-once
state and emissions through forced crashes — asserted per message.
"""
import collections
import os
import threading
import time

import msgpack
import pytest

from repro.core import (App, BusError, CoherenceError, DSLError, DurableError,
                        DurableLog, FieldSpec, KeyedStore, Message, MessageBus,
                        Operator, OperatorError, Retention, StreamSchema,
                        StreamSpec, connect, iter_log, resolve_replay_from,
                        schema_fingerprint)
from repro.core.compression import (CompressionError, codec_name, compress,
                                    decompress, train_dictionary)
from repro.core.durable import SNAPSHOT_TABLE as DURABLE_SNAPSHOT_TABLE
from repro.core.state import SNAPSHOT_TABLE as STATE_SNAPSHOT_TABLE
from repro.core.state import Database, StateError

KV = StreamSchema.of(k=FieldSpec("str"), v=FieldSpec("int"))


def _msg(subject: str, payload: dict, seq: int = 0) -> Message:
    return Message(subject=subject, payload=payload, seq=seq, ts=time.time())


def _drain(sub, timeout: float = 0.25):
    out = []
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        m = sub.next(timeout=0.02)
        if m is not None:
            out.append(m)
            deadline = time.monotonic() + timeout
    return out


# ---------------------------------------------------------------------------
# DurableLog unit behavior
# ---------------------------------------------------------------------------

def test_append_read_roundtrip_offsets():
    log = DurableLog("s", segment_records=4)
    for i in range(10):
        assert log.append(_msg("s", {"k": "a", "v": i}, seq=i)) == i
    assert log.next_offset() == 10
    assert log.earliest_offset() == 0
    msgs = log.read(0, max_n=100)
    assert [m.payload["v"] for m in msgs] == list(range(10))
    assert [m.headers["offset"] for m in msgs] == list(range(10))
    # mid-log reads honor the offset
    assert [m.payload["v"] for m in log.read(7)] == [7, 8, 9]
    # reads past the head are empty (caught up)
    assert log.read(10) == []


def test_segments_roll_and_retention_by_records():
    log = DurableLog("s", segment_records=4,
                     retention={"max_records": 8}, train_dict_after=0)
    for i in range(20):
        log.append(_msg("s", {"k": "a", "v": i}, seq=i))
    info = log.info()
    # whole sealed segments evicted oldest-first; the bound is approximate
    # by up to one segment but never exceeded by one full segment's worth
    assert info["depth"] <= 8 + 4
    assert info["evicted_segments"] >= 1
    assert info["evicted_records"] == info["evicted_segments"] * 4
    assert info["earliest_offset"] == info["evicted_records"]
    # reads below the earliest retained offset clamp instead of failing
    msgs = log.read(0, max_n=100)
    assert msgs[0].headers["offset"] == info["earliest_offset"]
    assert msgs[-1].headers["offset"] == 19


def test_retention_by_bytes_and_age():
    log = DurableLog("s", segment_records=2,
                     retention={"max_bytes": 1}, train_dict_after=0)
    for i in range(6):
        log.append(_msg("s", {"k": "a", "v": i}, seq=i))
    # every sealed segment is over a 1-byte budget; only the active remains
    assert log.info()["segments"] == 1
    log2 = DurableLog("s2", segment_records=2,
                      retention={"max_age_s": 3600}, train_dict_after=0)
    for i in range(6):
        log2.append(_msg("s2", {"k": "a", "v": i}, seq=i))
    assert log2.info()["evicted_segments"] == 0  # nothing is an hour old


def test_retention_validation():
    with pytest.raises(DurableError, match="unknown retention keys"):
        Retention.of({"max_msgs": 10})
    assert Retention.of(None) == Retention()
    r = Retention(max_records=5)
    assert Retention.of(r) is r


def test_offset_at_ts():
    log = DurableLog("s", segment_records=3, train_dict_after=0)
    for i in range(4):
        log.append(_msg("s", {"k": "a", "v": i}, seq=i))
    cut = time.time()
    time.sleep(0.01)
    for i in range(4, 8):
        log.append(_msg("s", {"k": "a", "v": i}, seq=i))
    assert log.offset_at_ts(0.0) == 0
    assert log.offset_at_ts(cut) == 4
    assert log.offset_at_ts(time.time() + 60) == 8  # future ts -> head


def test_persistence_roundtrip(tmp_path):
    root = str(tmp_path / "log")
    log = DurableLog("s", root=root, segment_records=4, train_dict_after=0)
    for i in range(10):
        log.append(_msg("s", {"k": "a", "v": i}, seq=i))
    log.close()
    assert os.path.exists(os.path.join(root, "catalog.dxc"))
    revived = DurableLog("s", root=root, segment_records=4,
                         train_dict_after=0)
    assert revived.next_offset() == 10
    assert [m.payload["v"] for m in revived.read(0, 100)] == list(range(10))
    # offsets continue where the previous incarnation stopped
    assert revived.append(_msg("s", {"k": "a", "v": 10}, seq=10)) == 10
    revived.drop()
    assert not os.path.exists(os.path.join(root, "catalog.dxc"))


def test_iter_log_and_fingerprint():
    log = DurableLog("s", segment_records=4, schema=KV, train_dict_after=0)
    for i in range(9):
        log.append(_msg("s", {"k": "a", "v": i}, seq=i))
    assert [m.payload["v"] for m in iter_log(log)] == list(range(9))
    assert [m.payload["v"] for m in iter_log(log, from_offset=5)] == [5, 6, 7, 8]
    assert log.info()["schema_fingerprint"] == schema_fingerprint(KV)
    assert schema_fingerprint(KV) == schema_fingerprint(KV)
    other = StreamSchema.of(k=FieldSpec("str"))
    assert schema_fingerprint(KV) != schema_fingerprint(other)
    assert schema_fingerprint(None) == "untyped"


# ---------------------------------------------------------------------------
# Dictionary-trained compression (satellite)
# ---------------------------------------------------------------------------

def test_train_dictionary_contract():
    samples = [f'{{"sensor": "lab-{i % 3}", "reading": {i}}}'.encode() * 4
               for i in range(32)]
    d = train_dictionary(samples)
    if codec_name() != "zstd":
        assert d is None
        return
    assert d is not None
    blob = compress(samples[0], dictionary=d)
    assert blob[:4] == b"DXZ2"
    assert decompress(blob, dictionary=d) == samples[0]
    # a dictionary blob is NOT self-describing: no/wrong dictionary fails
    with pytest.raises(CompressionError):
        decompress(blob)
    # too few samples -> no dictionary (graceful)
    assert train_dictionary(samples[:3]) is None


def test_log_trains_dictionary_and_reads_back():
    log = DurableLog("s", segment_records=8, train_dict_after=16)
    for i in range(40):
        log.append(_msg("s", {"k": f"sensor-{i % 4}", "v": i}, seq=i))
    info = log.info()
    assert info["dict_trained"] == (codec_name() == "zstd")
    # records written before AND after training decode fine
    assert [m.payload["v"] for m in log.read(0, 100)] == list(range(40))


def test_dictionary_persists_for_replay(tmp_path):
    if codec_name() != "zstd":
        pytest.skip("zstd not available — no dictionary to persist")
    root = str(tmp_path / "log")
    log = DurableLog("s", root=root, segment_records=8, train_dict_after=16)
    for i in range(30):
        log.append(_msg("s", {"k": f"sensor-{i % 4}", "v": i}, seq=i))
    log.close()
    assert os.path.exists(os.path.join(root, "dict.bin"))
    revived = DurableLog("s", root=root, segment_records=8)
    assert revived.info()["dict_trained"]
    assert [m.payload["v"] for m in revived.read(0, 100)] == list(range(30))


# -- dict-loss reopen fallback ----------------------------------------------
# A lost/corrupt dict.bin must degrade (drop only the DXZ2 segments, keep
# self-describing history, keep offsets dense), not fail the catalog load.
# Forged DXZ2 tags make these codec-independent — the readability classifier
# dispatches on the 4-byte blob tag, so the tests run on BOTH CI legs; the
# real-zstd end-to-end variant below runs wherever zstandard is installed.

def _seeded_root(tmp_path, n: int = 40) -> str:
    root = str(tmp_path / "log")
    log = DurableLog("s", root=root, segment_records=8, train_dict_after=0)
    for i in range(n):
        log.append(_msg("s", {"k": f"sensor-{i % 4}", "v": i}, seq=i))
    log.close()
    return root


def _forge_dict_blobs(root: str, bases: list[int]) -> None:
    """Re-tag sealed segment blobs as DXZ2 — on-disk state shaped exactly
    like a zstd leg with a trained dictionary would have written it."""
    for base in bases:
        path = os.path.join(root, f"seg-{base:012d}.dxl")
        with open(path, "rb") as f:
            obj = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
        obj["blob"] = b"DXZ2" + obj["blob"][4:]
        with open(path, "wb") as f:
            f.write(msgpack.packb(obj, use_bin_type=True))


def _rewrite_catalog(root: str, **updates) -> None:
    path = os.path.join(root, "catalog.dxc")
    with open(path, "rb") as f:
        cat = msgpack.unpackb(decompress(f.read()), raw=False,
                              strict_map_key=False)
    cat.update(updates)
    with open(path, "wb") as f:
        f.write(compress(msgpack.packb(cat, use_bin_type=True)))


def test_reopen_missing_dict_falls_back(tmp_path):
    root = _seeded_root(tmp_path)                # segs 0,8,16,24 + tail 32
    _forge_dict_blobs(root, [8, 16, 24])
    _rewrite_catalog(root, has_dict=True)        # ...but dict.bin is gone
    revived = DurableLog("s", root=root, segment_records=8)   # must not raise
    info = revived.info()
    # dictionary segments are gone (counted as evictions); self-describing
    # history and the raw-record tail survive, and offsets stay dense
    assert info["next_offset"] == 40
    assert info["evicted_records"] == 24 and info["evicted_segments"] == 3
    assert not info["dict_trained"]
    vals = [m.payload["v"] for m in revived.read(0, 100)]
    assert vals == list(range(8)) + list(range(32, 40))
    assert revived.append(_msg("s", {"k": "sensor-0", "v": 40}, seq=40)) == 40


def test_reopen_corrupt_dict_falls_back(tmp_path):
    root = _seeded_root(tmp_path)
    _forge_dict_blobs(root, [8, 16, 24])
    _rewrite_catalog(root, has_dict=True)
    with open(os.path.join(root, "dict.bin"), "wb") as f:
        f.write(b"definitely not a zstd dictionary")
    revived = DurableLog("s", root=root, segment_records=8)   # must not raise
    info = revived.info()
    assert info["next_offset"] == 40
    assert info["evicted_records"] == 24 and info["evicted_segments"] == 3
    assert not info["dict_trained"]
    assert [m.payload["v"] for m in revived.read(0, 100)] \
        == list(range(8)) + list(range(32, 40))


def test_reopen_unreadable_tail_keeps_offsets_monotone(tmp_path):
    root = _seeded_root(tmp_path, n=24)          # segs 0,8 + tail 16
    # crash-shaped state: the raw-record tail file never hit disk, so the
    # last on-disk segment is a dictionary blob — with the dictionary lost
    # it drops, and the fresh active segment must base at the catalog head
    _forge_dict_blobs(root, [8])
    _rewrite_catalog(root, has_dict=True)
    os.remove(os.path.join(root, f"seg-{16:012d}.dxl"))
    revived = DurableLog("s", root=root, segment_records=8)
    assert revived.next_offset() == 24
    assert revived.append(_msg("s", {"k": "sensor-0", "v": 24}, seq=24)) == 24
    assert [m.payload["v"] for m in revived.read(0, 100)] \
        == list(range(8)) + [24]


def test_reopen_missing_dict_real_zstd_end_to_end(tmp_path):
    if codec_name() != "zstd":
        pytest.skip("zstd not available — no real dictionary blobs to lose")
    root = str(tmp_path / "log")
    # a REAL trained-dictionary log: seg 0 seals before training (DXZ1),
    # later segments seal as DXZ2, the tail persists in raw-record form
    log = DurableLog("s", root=root, segment_records=8, train_dict_after=16)
    for i in range(40):
        log.append(_msg("s", {"k": f"sensor-{i % 4}", "v": i}, seq=i))
    log.close()
    os.remove(os.path.join(root, "dict.bin"))
    revived = DurableLog("s", root=root, segment_records=8)   # must not raise
    info = revived.info()
    assert info["next_offset"] == 40
    assert info["evicted_records"] == 24 and info["evicted_segments"] == 3
    assert [m.payload["v"] for m in revived.read(0, 100)] \
        == list(range(8)) + list(range(32, 40))


def test_zlib_history_survives_dict_loss_machinery(tmp_path, monkeypatch):
    # a log written on the zlib leg (every blob self-describing DXL1, no
    # dictionary) reopens losslessly regardless of codec availability —
    # the fallback path must never drop readable history
    import repro.core.compression as comp
    root = str(tmp_path / "log")
    with monkeypatch.context() as m:
        m.setattr(comp, "HAS_ZSTD", False)
        log = DurableLog("s", root=root, segment_records=8,
                         train_dict_after=16)
        for i in range(30):
            log.append(_msg("s", {"k": f"sensor-{i % 4}", "v": i}, seq=i))
        log.close()
    revived = DurableLog("s", root=root, segment_records=8)
    info = revived.info()
    assert info["evicted_records"] == 0 and info["evicted_segments"] == 0
    assert [m.payload["v"] for m in revived.read(0, 100)] == list(range(30))


# ---------------------------------------------------------------------------
# Bus integration: publish appends, replay_from, gapless handoff
# ---------------------------------------------------------------------------

@pytest.fixture
def bus():
    b = MessageBus()
    b.register_subject("s", KV)
    b.make_durable("s", retention={"max_records": 10_000})
    yield b
    b.close()


def test_publish_appends_and_stamps_offset(bus):
    tok = bus.issue_token("t", ["s"])
    sub = bus.subscribe("s", token=tok)
    for i in range(5):
        bus.publish("s", {"k": "a", "v": i}, token=tok)
    msgs = _drain(sub)
    assert [m.headers["offset"] for m in msgs] == list(range(5))
    assert bus.durable_log("s").next_offset() == 5
    with pytest.raises(BusError):
        bus.make_durable("s")  # one log per subject


def test_replay_then_live_no_gap_no_dup(bus):
    tok = bus.issue_token("t", ["s"])
    for i in range(50):
        bus.publish("s", {"k": "a", "v": i}, token=tok)
    sub = bus.subscribe("s", token=tok, replay_from="earliest")
    assert sub.replaying
    # publish MORE while the replay is still draining
    got, published = [], 50
    while True:
        batch = sub.next_batch(8, timeout=0.05)
        if published < 80:  # interleave publishes with replay reads
            for _ in range(10):
                bus.publish("s", {"k": "a", "v": published}, token=tok)
                published += 1
        if not batch and published >= 80:
            break
        got.extend(batch)
    assert [m.payload["v"] for m in got] == list(range(80))  # no gap, no dup
    assert not sub.replaying
    assert sub.replayed >= 50
    # and the flip is permanent: later publishes arrive live (the mailbox
    # first dedupes the live copies that queued during the replay)
    bus.publish("s", {"k": "a", "v": 80}, token=tok)
    live = _drain(sub, timeout=0.5)
    assert [m.payload["v"] for m in live] == [80]
    assert sub.deduped > 0


def test_replay_from_offset_and_timestamp(bus):
    tok = bus.issue_token("t", ["s"])
    for i in range(6):
        bus.publish("s", {"k": "a", "v": i}, token=tok)
    cut = time.time()
    time.sleep(0.01)
    for i in range(6, 10):
        bus.publish("s", {"k": "a", "v": i}, token=tok)
    by_offset = bus.subscribe("s", token=tok, replay_from=7)
    assert [m.payload["v"] for m in _drain(by_offset)] == [7, 8, 9]
    by_ts = bus.subscribe("s", token=tok, replay_from=cut)
    assert [m.payload["v"] for m in _drain(by_ts)] == [6, 7, 8, 9]


def test_replay_requires_durable_subject():
    b = MessageBus()
    b.register_subject("fire", KV)
    tok = b.issue_token("t", ["fire"])
    with pytest.raises(BusError, match="not durable"):
        b.subscribe("fire", token=tok, replay_from="earliest")
    with pytest.raises(BusError):
        b.subscribe("fire", token=tok, replay_from=True)  # bool is not an offset
    b.close()


def test_broadcast_overflow_heals_from_log(bus):
    tok = bus.issue_token("t", ["s"])
    sub = bus.subscribe("s", token=tok, maxsize=4)
    for i in range(32):  # overflows the 4-deep mailbox -> drop-oldest
        bus.publish("s", {"k": "a", "v": i}, token=tok)
    msgs = _drain(sub, timeout=0.5)
    # the gap left by drop-oldest is healed from the durable log: the
    # subscriber still observes every offset exactly once, in order
    assert [m.payload["v"] for m in msgs] == list(range(32))
    assert sub.healed > 0


def test_durable_stats_surface(bus):
    tok = bus.issue_token("t", ["s"])
    sub = bus.subscribe("s", token=tok, replay_from="earliest", name="r")
    for i in range(3):
        bus.publish("s", {"k": "a", "v": i}, token=tok)
    _drain(sub)
    st = bus.stats()["s"]
    assert st["durable"]["depth"] == 3
    assert st["durable"]["next_offset"] == 3
    rsub = st["subscriptions"]["r"]
    assert rsub["replayed"] == 3
    assert rsub["replaying"] is False


# ---------------------------------------------------------------------------
# Group guard (satellite bugfix): replaying member is not a live target
# ---------------------------------------------------------------------------

def test_replaying_member_not_picked_until_caught_up(bus):
    tok = bus.issue_token("t", ["s"])
    a = bus.subscribe("s", token=tok, group="pool", name="a")
    for i in range(12):
        bus.publish("s", {"k": "a", "v": i}, token=tok)
    assert len(_drain(a)) == 12
    # b joins late and replays; while catching up it must NOT count as a
    # healthy member for live round-robin — its share of live traffic
    # would sit behind the whole history (and the overlap would be duped)
    b = bus.subscribe("s", token=tok, group="pool", name="b",
                      replay_from="earliest")
    assert b.replaying
    bus.publish("s", {"k": "a", "v": 12}, token=tok)
    live = a.next(timeout=0.5)
    assert live is not None and live.payload["v"] == 12  # a got it, not b
    snap = bus.group_info("s", "pool")
    assert snap["replaying"] == ["b"]
    # b replays the full history (including v=12, published after its
    # replay started) and flips
    got_b = _drain(b, timeout=0.5)
    assert [m.payload["v"] for m in got_b] == list(range(13))
    assert not b.replaying
    # once caught up, b shares live round-robin again
    for i in range(13, 21):
        bus.publish("s", {"k": "a", "v": i}, token=tok)
    more_a, more_b = _drain(a), _drain(b)
    assert len(more_a) > 0 and len(more_b) > 0
    assert sorted(m.payload["v"] for m in more_a + more_b) == list(range(13, 21))


def test_keyed_member_replay_overlap_is_deduped(bus):
    tok = bus.issue_token("t", ["s"])
    a = bus.subscribe("s", token=tok, group="pool", key="k", name="a")
    for i in range(10):
        bus.publish("s", {"k": f"key-{i % 4}", "v": i}, token=tok)
    assert len(_drain(a)) == 10
    # a keyed member STAYS in the ring while replaying (its partitions must
    # not move twice); live messages queue behind the replay and the
    # overlap is dropped by the frozen dedupe window at the flip
    b = bus.subscribe("s", token=tok, group="pool", key="k", name="b",
                      replay_from="earliest")
    for i in range(10, 20):
        bus.publish("s", {"k": f"key-{i % 4}", "v": i}, token=tok)
    got_a = [m.payload["v"] for m in _drain(a, timeout=0.5)]
    got_b = [m.payload["v"] for m in _drain(b, timeout=0.5)]
    # b replays 0..9 (+ any of 10..19 read from the log before its flip);
    # between them every message is seen, and b never sees one twice
    assert sorted(set(got_b)) == got_b  # no dup within b
    assert sorted(got_a + [v for v in got_b if v >= 10]) == list(range(10, 20))
    assert set(got_b) >= set(range(10)) - set(got_a)


# ---------------------------------------------------------------------------
# KeyedStore: TTL / max_keys / exactly-once apply (satellite)
# ---------------------------------------------------------------------------

def test_snapshot_table_constants_agree():
    assert STATE_SNAPSHOT_TABLE == DURABLE_SNAPSHOT_TABLE


def test_keyed_store_ttl_expiry_and_compaction():
    store = KeyedStore(None, "t", ttl=0.05)
    store.put("a", 1)
    store.put("b", 2)
    assert store.get("a") == 1
    time.sleep(0.08)
    assert store.get("a", "gone") == "gone"   # lazy expiry on access
    assert store.expired >= 1
    removed = store.compact()                  # sweep the rest
    assert removed >= 1
    assert len(store) == 0
    assert store.stats()["expired"] == 2


def test_keyed_store_max_keys_evicts_oldest():
    store = KeyedStore(None, "t", max_keys=3)
    for i in range(5):
        store.put(f"k{i}", i)
        time.sleep(0.002)  # distinct write ts -> deterministic eviction order
    assert len(store) == 3
    assert store.get("k0") is None and store.get("k1") is None
    assert store.get("k4") == 4
    assert store.evicted == 2
    with pytest.raises(StateError):
        KeyedStore(None, "t2", max_keys=0)
    with pytest.raises(StateError):
        KeyedStore(None, "t3", ttl=-1)


def test_apply_once_offset_dedupe():
    store = KeyedStore(None, "t")
    v, applied = store.apply_once("a", 5, lambda acc: (acc or 0) + 1)
    assert (v, applied) == (1, True)
    # same offset again (replay overlapping live): skipped, value unchanged
    v, applied = store.apply_once("a", 5, lambda acc: (acc or 0) + 1)
    assert (v, applied) == (1, False)
    # stale offset: also skipped
    v, applied = store.apply_once("a", 3, lambda acc: (acc or 0) + 1)
    assert (v, applied) == (1, False)
    # newer offset applies
    v, applied = store.apply_once("a", 6, lambda acc: (acc or 0) + 1)
    assert (v, applied) == (2, True)
    assert store.applied_offset("a") == 6
    # offset=None (non-durable input) always applies, keeps the watermark
    v, applied = store.apply_once("a", None, lambda acc: acc + 10)
    assert (v, applied) == (12, True)
    assert store.applied_offset("a") == 6


def test_snapshot_watermark_resolution(tmp_path):
    db = Database("d", "filekv", str(tmp_path / "d.dxdb"))
    store = KeyedStore(db, "reduce", ttl=1000)
    store.apply_once("a", 7, lambda acc: 1)
    info = store.snapshot("inst-0", 7)
    assert info["watermark"] == 7
    store.apply_once("b", 9, lambda acc: 2)
    store.snapshot("inst-1", 9)
    # resolution replays the suffix after the OLDEST watermark — the
    # conservative member bounds everyone (apply_once makes the extra
    # replay harmless)
    assert resolve_replay_from("snapshot", db) == 8
    assert store.last_snapshot()["watermark"] == 9
    assert store.last_snapshot("inst-0")["watermark"] == 7
    # snapshots survive a process restart (the db IS the state snapshot)
    db2 = Database("d", "filekv", str(tmp_path / "d.dxdb"))
    assert resolve_replay_from("snapshot", db2) == 8
    # no snapshots / no db -> replay everything
    assert resolve_replay_from("snapshot", None) == "earliest"
    assert resolve_replay_from("snapshot", Database("empty")) == "earliest"
    # passthrough for every other form
    assert resolve_replay_from(17, db) == 17
    assert resolve_replay_from("earliest", db) == "earliest"
    assert resolve_replay_from(None, db) is None


def test_snapshot_skips_expired_keys():
    store = KeyedStore(None, "t", ttl=0.05)
    store.apply_once("a", 1, lambda acc: 1)
    time.sleep(0.08)
    store.apply_once("b", 2, lambda acc: 2)
    info = store.snapshot("inst-0", 2)
    assert info["keys"] == 1  # "a" expired and was compacted away
    assert store.get("a") is None


# ---------------------------------------------------------------------------
# Forced crash: exactly-once keyed recovery, asserted per message
# ---------------------------------------------------------------------------

def test_forced_crash_recovery_zero_lost_zero_duped():
    """A keyed stateful member crashes mid-run WITH unprocessed in-flight
    messages (popped from its mailbox, never applied — fire-and-forget would
    lose them).  A replacement replays from the snapshot watermark; per-key
    sequences must come out with 0 lost, 0 double-applied, 0 out-of-order —
    asserted on every single message by the fold itself."""
    bus = MessageBus()
    bus.register_subject("ev", KV)
    bus.make_durable("ev")
    tok = bus.issue_token("t", ["ev"])
    db = Database("recov")
    store = KeyedStore(db, "reduce")
    violations: list[str] = []
    emitted: collections.Counter = collections.Counter()
    seq_of: collections.Counter = collections.Counter()

    def fold(payload):
        def _fn(acc):
            acc = list(acc or [])
            if payload["v"] != len(acc):   # per-message order/gap assertion
                violations.append(f"key {payload['k']}: got {payload['v']} "
                                  f"after {len(acc)} applies")
            return acc + [payload["v"]]
        return _fn

    def pump(sub, n=10_000):
        for m in sub.next_batch(n, timeout=0.2) or []:
            value, applied = store.apply_once(
                m.payload["k"], m.headers["offset"], fold(m.payload))
            if applied:
                emitted[(m.payload["k"], m.payload["v"])] += 1

    def publish(count):
        for _ in range(count):
            k = f"key-{sum(seq_of.values()) % 5}"
            bus.publish("ev", {"k": k, "v": seq_of[k]}, token=tok)
            seq_of[k] += 1

    a = bus.subscribe("ev", token=tok, group="pool", key="k", name="a")
    b = bus.subscribe("ev", token=tok, group="pool", key="k", name="b")
    publish(40)
    pump(a), pump(b)
    store.snapshot("a", 39)  # both members are caught up through offset 39

    publish(30)
    pump(a)                  # the survivor keeps applying its partitions
    # CRASH: b pops its entire backlog and dies before applying any of it —
    # those messages are destroyed in flight (single delivery: the popped
    # copies were the only ones)
    doomed = b.next_batch(10_000, timeout=0.2) or []
    assert doomed, "crash scenario needs in-flight messages to destroy"
    bus.unsubscribe(b)

    # RECOVERY: replacement member replays the suffix after the snapshot
    # watermark; apply_once discards everything the store already absorbed
    start = resolve_replay_from("snapshot", db)
    assert start == 40
    b2 = bus.subscribe("ev", token=tok, group="pool", key="k", name="b2",
                       replay_from=start)
    publish(30)              # traffic continues during recovery
    deadline = time.monotonic() + 5.0
    total = sum(seq_of.values())
    while time.monotonic() < deadline:
        pump(a), pump(b2)
        done = sum(len(store.get(k) or []) for k in list(seq_of))
        if done >= total and not b2.replaying:
            break

    assert violations == []                                # 0 out-of-order
    for k, n in seq_of.items():
        assert store.get(k) == list(range(n)), f"lost updates on {k}"  # 0 lost
    assert all(c == 1 for c in emitted.values())            # 0 double-emitted
    assert len(emitted) == sum(seq_of.values())
    bus.close()


# ---------------------------------------------------------------------------
# Property test: any publish/crash/replay schedule keeps per-key order
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - minimal CI leg
    HAS_HYPOTHESIS = False


def _run_schedule(schedule):
    """Random interleavings of publishes and member crashes (with
    snapshot recovery) must deliver, per key, exactly the durable log's
    per-key sequence — no gaps, no dupes at any handoff."""
    bus = MessageBus()
    bus.register_subject("ev", KV)
    bus.make_durable("ev")
    tok = bus.issue_token("t", ["ev"])
    db = Database("prop")
    store = KeyedStore(db, "reduce")
    seq_of: collections.Counter = collections.Counter()
    applied_seqs: dict[str, list[int]] = collections.defaultdict(list)
    # single member + in-order delivery/replay => applied offsets are
    # contiguous, so the member's true recovery watermark is simply the
    # highest offset it applied
    hwm = [-1]

    def pump(sub):
        for m in sub.next_batch(10_000, timeout=0) or []:
            off = m.headers["offset"]

            def _fn(acc, p=m.payload, off=off):
                applied_seqs[p["k"]].append(p["v"])
                hwm[0] = max(hwm[0], off)
                return (acc or 0) + 1
            store.apply_once(m.payload["k"], off, _fn)

    member = bus.subscribe("ev", token=tok, group="pool", key="k",
                           name="m0")
    generation = 1
    pumped = 0
    for op in schedule:
        if op[0] == "pub":
            k = f"key-{op[1]}"
            bus.publish("ev", {"k": k, "v": seq_of[k]}, token=tok)
            seq_of[k] += 1
            pumped += 1
            if pumped % 3 == 0:  # drain periodically, not every publish
                pump(member)
        else:
            # crash: destroy the member's in-flight backlog, then
            # recover a replacement from the snapshot watermark
            member.next_batch(10_000, timeout=0)  # popped, never applied
            bus.unsubscribe(member)
            if hwm[0] >= 0:
                store.snapshot(f"m{generation - 1}", hwm[0])
            member = bus.subscribe(
                "ev", token=tok, group="pool", key="k",
                name=f"m{generation}",
                replay_from=resolve_replay_from("snapshot", db))
            generation += 1
    # drive replay + live to quiescence
    for _ in range(200):
        pump(member)
        done = all(len(applied_seqs[k]) >= n for k, n in seq_of.items())
        if done and not member.replaying:
            break
    for k, n in seq_of.items():
        assert applied_seqs[k] == list(range(n)), \
            f"{k}: applied {applied_seqs[k]} != published {list(range(n))}"
    bus.close()


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(
        st.one_of(
            st.tuples(st.just("pub"), st.integers(min_value=0, max_value=3)),
            st.just(("crash",)),
        ),
        min_size=4, max_size=60))
    def test_any_schedule_matches_log_order(schedule):
        _run_schedule(schedule)


def test_seeded_schedules_match_log_order():
    """Seeded stand-in for the hypothesis property when hypothesis is not
    installed (the minimal CI leg): 50 reproducible random publish/crash
    schedules through the same runner."""
    import random
    rng = random.Random(0xDA7A)
    for _ in range(50):
        schedule = [("crash",) if rng.random() < 0.15
                    else ("pub", rng.randrange(4))
                    for _ in range(rng.randint(4, 60))]
        _run_schedule(schedule)


# ---------------------------------------------------------------------------
# Operator / DSL plumbing
# ---------------------------------------------------------------------------

def _identity_au(name="ident"):
    from repro.core import AnalyticsUnitSpec
    return AnalyticsUnitSpec(name=name,
                             logic=lambda ctx: lambda s, p: p)


def test_operator_validates_durability_coherence():
    from repro.core import DriverSpec, SensorSpec
    op = Operator()
    op.register_analytics_unit(_identity_au())
    op.register_driver(DriverSpec(name="feed", logic=lambda ctx: iter(())))
    op.register_sensor(SensorSpec(name="ext", driver="feed"))  # fire-and-forget
    # retention without durable is a contradiction
    with pytest.raises(OperatorError, match="retention"):
        op.create_stream(StreamSpec(name="out", analytics_unit="ident",
                                    inputs=("ext",),
                                    retention={"max_records": 10}))
    # replay_from demands durable inputs
    with pytest.raises(CoherenceError, match="durable"):
        op.create_stream(StreamSpec(name="out", analytics_unit="ident",
                                    inputs=("ext",), replay_from="earliest"))
    op.shutdown()


def test_dsl_eager_checks():
    app = App("checks")

    @app.driver
    def feed(ctx):
        return iter(())

    s = app.sense("src", feed)
    with pytest.raises(DSLError, match="retention"):
        s.durable(retention={"bogus": 1})
    m = s.map(lambda p: p, name="m")
    with pytest.raises(DSLError, match="durable inputs"):
        m.replay(from_="earliest")
    with pytest.raises(DSLError):
        m.replay(from_=True)           # bool is not an offset
    with pytest.raises(DSLError):
        s.replay(from_="earliest")     # sensors have no inputs to replay
    with pytest.raises(DSLError):
        app.external("other").durable()  # not ours to make durable
    s.durable()                        # sensor streams can be durable
    m.replay(from_="snapshot")         # now the input is durable
    with pytest.raises(DSLError, match="snapshot_every"):
        s.key_by("k").reduce(lambda a, p: a, snapshot_every=0)


def test_dsl_durable_replay_end_to_end():
    app = App("e2e")

    @app.driver
    def feeder(ctx, n=30):
        def gen():
            for i in range(n):
                yield {"k": f"k{i % 3}", "v": i}
        return gen()

    src = app.sense("events", feeder).durable(
        retention={"max_records": 1000})
    totals = src.key_by("k").reduce(
        lambda acc, p: (acc or 0) + p["v"], name="totals", snapshot_every=5)
    totals.durable().replay(from_="snapshot")

    with connect() as op:
        app.deploy(op)
        time.sleep(1.5)
        st = op.bus.stats()
        assert st["events"]["durable"]["depth"] == 30
        assert st["totals"]["durable"]["depth"] == 30
        # the reduce instance snapshots its watermark as it folds
        h = next(h for iid, h in op.executor._instances.items()
                 if iid.startswith("totals/"))
        m = h.sidecar.metrics()
        assert m["snapshots"] >= 5
        assert m["snapshot_age_s"] is not None
        assert set(m["durable"]) == {"events", "totals"}
        # a late joiner replays the full durable output
        late = op.subscribe("totals", replay_from="earliest")
        vals = collections.defaultdict(int)
        got = _drain(late, timeout=0.5)
        assert len(got) == 30            # every fold emitted exactly once
        for msg in got:
            vals[msg.payload["k"]] = msg.payload["value"]
        assert vals == {f"k{r}": sum(range(r, 30, 3)) for r in range(3)}


def test_operator_restart_resumes_from_snapshot(tmp_path):
    """Durable logs + snapshot watermarks survive an operator restart: the
    second incarnation replays only the unapplied suffix and emits nothing
    twice, even though replay_from="snapshot" re-reads applied history."""
    def run(phase, lo, hi):
        app = App("restart")

        @app.driver
        def feeder(ctx, lo=0, hi=0):
            def gen():
                time.sleep(0.3)  # let the test's live subscriber attach
                for i in range(lo, hi):
                    yield {"k": f"k{i % 2}", "v": i}
            return gen()

        src = app.sense("events", feeder, lo=lo, hi=hi).durable()
        totals = src.key_by("k").reduce(
            lambda acc, p: (acc or 0) + 1, name="totals", snapshot_every=2)
        totals.replay(from_="snapshot")
        with connect(state_root=str(tmp_path / "state")) as op:
            app.deploy(op)
            sub = op.subscribe("totals")
            time.sleep(1.5)
            return [m.payload for m in _drain(sub, timeout=0.5)]

    first = run(1, 0, 12)
    assert len(first) == 12
    second = run(2, 12, 20)
    # run 2 replays the log suffix from the snapshot; everything already
    # folded in run 1 is skipped (0 duplicate emissions), the 8 new
    # messages are folded ON TOP of the recovered counts
    assert len(second) == 8
    finals = {}
    for p in second:
        finals[p["k"]] = p["value"]
    assert finals == {"k0": 10, "k1": 10}  # 20 messages, 2 keys, counted once


# ---------------------------------------------------------------------------
# Fusion barriers
# ---------------------------------------------------------------------------

def _device_chain_app(durable_mid=False):
    app = App("fuse")

    @app.driver
    def feed(ctx):
        return iter(())

    s = app.sense("src", feed)
    a = s.map(lambda p: p, name="a", device=True)
    if durable_mid:
        a.durable()
    a.map(lambda p: p, name="b", device=True) \
     .map(lambda p: p, name="c", device=True)
    return app


def test_durable_interior_stream_is_fusion_barrier():
    base = _device_chain_app().build()
    assert sorted(s.name for s in base.streams) == ["c"]  # a+b+c fuse
    split = _device_chain_app(durable_mid=True).build()
    names = sorted(s.name for s in split.streams)
    assert names == ["a", "c"]  # durable 'a' stays a subject; b+c fuse
    a_spec = next(s for s in split.streams if s.name == "a")
    assert a_spec.durable


def test_fused_segment_carries_entry_replay_and_exit_durability():
    app = App("carry")

    @app.driver
    def feed(ctx):
        return iter(())

    src = app.sense("src", feed).durable()
    a = src.map(lambda p: p, name="a", device=True).replay(from_="earliest")
    b = a.map(lambda p: p, name="b", device=True)
    b.durable(retention={"max_records": 64})
    appl = app.build()
    assert [s.name for s in appl.streams] == ["b"]
    fused = appl.streams[0]
    assert fused.replay_from == "earliest"     # entry's replay
    assert fused.durable                       # exit's log
    assert fused.retention == {"max_records": 64}
    assert fused.inputs == ("src",)
