"""Queue-group delivery (tentpole PR 3): scaled instances are a worker pool.

Bus level: ``subscribe(..., group=...)`` members split each message
round-robin (single delivery per group), different groups and ungrouped
subscribers keep broadcast semantics, dead members are skipped, drops are
counted per subscription and per group.

Platform level: scaled instances of one stream share the group named after
the stream (``StreamSpec.delivery="group"``, the default), fused device
units join as one member per instance, ``delivery="broadcast"`` restores
replica semantics, and the DSL ``.scaled()`` escape hatch drives both.
"""
import time

import pytest

from repro.core import (AnalyticsUnitSpec, App, AutoScaler, ConfigSchema,
                        DriverSpec, DSLError, FieldSpec, MessageBus, Operator,
                        OperatorError, Placement, ScalePolicy, SensorSpec,
                        StreamSchema, StreamSpec, drain)

INT_SCHEMA = StreamSchema.of(value=FieldSpec("int"))


# ---------------------------------------------------------------------------
# Bus-level semantics
# ---------------------------------------------------------------------------

@pytest.fixture
def bus():
    b = MessageBus()
    b.register_subject("s", INT_SCHEMA)
    return b


def _drain_now(sub):
    out = []
    while True:
        m = sub.next(timeout=0)
        if m is None:
            return out
        out.append(m.payload["value"])


def test_group_members_split_round_robin(bus):
    tok = bus.issue_token("t", ["s"])
    members = [bus.subscribe("s", token=tok, group="pool", name=f"m{i}")
               for i in range(3)]
    for i in range(9):
        bus.publish("s", {"value": i}, token=tok)
    got = [_drain_now(m) for m in members]
    # single delivery: every message reaches exactly one member …
    assert sorted(v for g in got for v in g) == list(range(9))
    # … and round-robin splits them evenly
    assert [len(g) for g in got] == [3, 3, 3]


def test_same_subject_different_groups_broadcast(bus):
    """§3 stream reuse: each *group* sees every message; members share it."""
    tok = bus.issue_token("t", ["s"])
    a1 = bus.subscribe("s", token=tok, group="app-a", name="a1")
    a2 = bus.subscribe("s", token=tok, group="app-a", name="a2")
    b1 = bus.subscribe("s", token=tok, group="app-b", name="b1")
    solo = bus.subscribe("s", token=tok, name="solo")  # ungrouped broadcast
    n = 8
    for i in range(n):
        bus.publish("s", {"value": i}, token=tok)
    assert sorted(_drain_now(a1) + _drain_now(a2)) == list(range(n))
    assert _drain_now(b1) == list(range(n))
    assert _drain_now(solo) == list(range(n))


def test_member_death_mid_rotation_reroutes(bus):
    tok = bus.issue_token("t", ["s"])
    a = bus.subscribe("s", token=tok, group="pool", name="a")
    b = bus.subscribe("s", token=tok, group="pool", name="b")
    bus.publish("s", {"value": 0}, token=tok)   # -> a
    bus.publish("s", {"value": 1}, token=tok)   # -> b
    a.close()  # died, not yet unsubscribed (crash before reap)
    for i in range(2, 6):
        bus.publish("s", {"value": i}, token=tok)
    assert _drain_now(b) == [1, 2, 3, 4, 5]     # survivors absorb the share
    bus.unsubscribe(a)                           # reap: a's queued 0 re-routes
    bus.publish("s", {"value": 6}, token=tok)
    assert _drain_now(b) == [0, 6]
    assert bus.stats()["s"]["groups"]["pool"]["members"] == ["b"]


def test_departing_member_backlog_reroutes_to_survivors(bus):
    """Unsubscribing a member (scale-down, straggler replacement, crash reap)
    hands its queued share — the only copies — to the surviving members."""
    tok = bus.issue_token("t", ["s"])
    a = bus.subscribe("s", token=tok, group="pool", name="a")
    b = bus.subscribe("s", token=tok, group="pool", name="b")
    for i in range(6):
        bus.publish("s", {"value": i}, token=tok)   # a: 0,2,4  b: 1,3,5
    bus.unsubscribe(a)
    assert _drain_now(b) == [1, 3, 5, 0, 2, 4]      # share appended, not lost
    assert bus.stats()["s"]["groups"]["pool"]["rerouted"] == 3


def test_offer_to_closed_mailbox_is_counted(bus):
    """A message offered after close (e.g. a publish racing a departure) is
    refused but never silently lost from the books."""
    tok = bus.issue_token("t", ["s"])
    sub = bus.subscribe("s", token=tok, name="x")
    sub.close()
    bus.publish("s", {"value": 0}, token=tok)
    assert sub.dropped == 1


def test_last_member_departure_counts_losses(bus):
    tok = bus.issue_token("t", ["s"])
    a = bus.subscribe("s", token=tok, group="pool", name="a")
    for i in range(4):
        bus.publish("s", {"value": i}, token=tok)
    bus.unsubscribe(a)
    assert a.dropped == 4                            # lost share is accounted
    st = bus.stats()["s"]
    assert "pool" not in st["groups"]
    assert st["lost"] == 4       # …and stays visible after the sub is gone


def test_group_with_no_healthy_member_counts_undeliverable(bus):
    tok = bus.issue_token("t", ["s"])
    a = bus.subscribe("s", token=tok, group="pool", name="a")
    a.close()
    bus.publish("s", {"value": 0}, token=tok)
    g = bus.stats()["s"]["groups"]["pool"]
    assert g["undeliverable"] == 1 and g["delivered"] == 0


def test_stats_surface_membership_rotation_and_drops(bus):
    tok = bus.issue_token("t", ["s"])
    bus.subscribe("s", token=tok, group="pool", name="a", maxsize=2)
    bus.subscribe("s", token=tok, group="pool", name="b", maxsize=2)
    for i in range(10):
        bus.publish("s", {"value": i}, token=tok)
    st = bus.stats()["s"]
    g = st["groups"]["pool"]
    assert g["members"] == ["a", "b"]
    assert g["delivered"] == 10
    assert g["rr"] == 0                          # 10 messages over 2 members
    # each mailbox holds 2 of its 5, so 3 dropped per subscription
    assert g["dropped"] == 6
    assert st["subscriptions"]["a"]["dropped"] == 3
    assert st["subscriptions"]["b"]["group"] == "pool"
    assert st["dropped"] == 6                    # subject-level aggregate


def test_pick_rotation_no_skew_after_member_removal(bus):
    """Regression (PR 4): removing a member must never skew the rotation.

    The cursor tracks the next member's *identity* (index arithmetic around
    a shrinking list is how the survivor after a departure gets
    double-picked).  Exhaustively: for every pool size, cursor position and
    removal index, the picks immediately after a removal must (a) start at
    the removed member's successor when the cursor pointed at the victim,
    and (b) cover every survivor exactly once per rotation — no survivor
    double-picked, none starved."""
    from repro.core import QueueGroup, Subscription

    for n in (2, 3, 4, 5):
        for advance in range(n):
            for kill in range(n):
                g = QueueGroup("s", "g")
                subs = [Subscription("s", 8, False, name=f"m{i}")
                        for i in range(n)]
                for s in subs:
                    g.add(s)
                for _ in range(advance):
                    g.pick()
                victim = subs[kill]
                cursor_was_victim = g.snapshot()["members"][
                    g.snapshot()["rr"]] == victim.name
                g.remove(victim)
                survivors = [s for s in subs if s is not victim]
                if not survivors:
                    continue
                window = [g.pick()[0] for _ in range(len(survivors))]
                case = (n, advance, kill)
                assert sorted(m.name for m in window) == \
                    sorted(s.name for s in survivors), case
                if cursor_was_victim:
                    successor = subs[(kill + 1) % n]
                    expect = successor if successor is not victim \
                        else survivors[0]
                    assert window[0] is expect, case


def test_pick_rotation_no_skew_removing_closed_member(bus):
    """Same invariant when the removed member was already closed (crash
    before reap): the rotation had been skipping it, and its removal must
    not double-pick whoever absorbed its turns."""
    from repro.core import QueueGroup, Subscription

    for advance in range(4):
        g = QueueGroup("s", "g")
        subs = [Subscription("s", 8, False, name=f"m{i}") for i in range(4)]
        for s in subs:
            g.add(s)
        subs[1].closed = True
        for _ in range(advance):
            g.pick()
        g.remove(subs[1])
        survivors = [subs[0], subs[2], subs[3]]
        window = [g.pick()[0] for _ in range(3)]
        assert sorted(m.name for m in window) == \
            sorted(s.name for s in survivors), advance


def test_group_backlog_is_member_sum(bus):
    tok = bus.issue_token("t", ["s"])
    bus.subscribe("s", token=tok, group="pool", name="a")
    bus.subscribe("s", token=tok, group="pool", name="b")
    for i in range(6):
        bus.publish("s", {"value": i}, token=tok)
    assert bus.backlog("s") == 6                 # pool shares one logical queue


# ---------------------------------------------------------------------------
# Platform level: operator / fused units / DSL
# ---------------------------------------------------------------------------

def counter_driver(ctx):
    def gen():
        for i in range(int(ctx.config.get("n", 50))):
            if not ctx.running:
                return
            yield {"value": i}
    return gen()


def identity_au(ctx):
    return lambda stream, payload: {"value": payload["value"]}


def _operator() -> Operator:
    op = Operator(reconcile_interval_s=0.05)
    op.register_driver(DriverSpec(
        name="counter", logic=counter_driver,
        config_schema=ConfigSchema.of(n=("int", 50)),
        output_schema=INT_SCHEMA))
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="ident", logic=identity_au, output_schema=INT_SCHEMA,
        max_instances=8))
    return op


def test_scaled_stream_delivers_each_message_once():
    """delivery='group' (default): 3 instances, every message exactly once."""
    op = _operator()
    try:
        op.register_sensor(SensorSpec(name="nums", driver="counter",
                                      config={"n": 30}), start=False)
        op.create_stream(StreamSpec(name="out", analytics_unit="ident",
                                    inputs=("nums",), fixed_instances=3))
        sub = op.subscribe("out")
        op.start_pending_sensors()
        vals = sorted(m.payload["value"] for m in drain(sub, 30))
        assert vals == list(range(30))           # no duplicates, no losses
        assert sub.next(timeout=0.3) is None     # and nothing extra arrives
        g = op.bus.stats()["nums"]["groups"]["out"]
        assert len(g["members"]) == 3 and g["delivered"] == 30
    finally:
        op.shutdown()


def test_broadcast_delivery_restores_replicas():
    op = _operator()
    try:
        op.register_sensor(SensorSpec(name="nums", driver="counter",
                                      config={"n": 10}), start=False)
        op.create_stream(StreamSpec(name="out", analytics_unit="ident",
                                    inputs=("nums",), fixed_instances=2,
                                    delivery="broadcast"))
        sub = op.subscribe("out", maxsize=64)
        op.start_pending_sensors()
        vals = sorted(m.payload["value"] for m in drain(sub, 20))
        assert vals == sorted(list(range(10)) * 2)   # every replica re-emits
        assert op.bus.stats()["nums"]["groups"] == {}
    finally:
        op.shutdown()


def test_gadget_group_never_merges_with_same_named_stream():
    """Gadget and stream names live in different namespaces — their queue
    groups on a shared input subject must too, or each would see only half
    the messages."""
    from repro.core import ActuatorSpec, GadgetSpec

    op = _operator()
    try:
        seen: list[int] = []
        op.register_actuator(ActuatorSpec(
            name="sink",
            logic=lambda ctx: (lambda s, p: seen.append(p["value"]))))
        op.register_sensor(SensorSpec(name="nums", driver="counter",
                                      config={"n": 12}), start=False)
        op.create_stream(StreamSpec(name="alerts", analytics_unit="ident",
                                    inputs=("nums",), fixed_instances=1))
        op.register_gadget(GadgetSpec(name="alerts", actuator="sink",
                                      inputs=("nums",)))
        sub = op.subscribe("alerts")
        op.start_pending_sensors()
        vals = sorted(m.payload["value"] for m in drain(sub, 12))
        assert vals == list(range(12))           # stream saw ALL messages
        deadline = time.monotonic() + 5
        while len(seen) < 12 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert sorted(seen) == list(range(12))   # so did the gadget
        groups = op.bus.stats()["nums"]["groups"]
        assert set(groups) == {"alerts", "gadget:alerts"}
    finally:
        op.shutdown()


def test_invalid_delivery_rejected():
    op = _operator()
    try:
        op.register_sensor(SensorSpec(name="nums", driver="counter"))
        with pytest.raises(OperatorError):
            op.create_stream(StreamSpec(name="out", analytics_unit="ident",
                                        inputs=("nums",), delivery="anycast"))
    finally:
        op.shutdown()


def test_fused_unit_instances_join_one_group():
    """Fused DEVICE segments scale as single-delivery pool members too."""
    op = Operator(reconcile_interval_s=0.05)
    try:
        app = App("fused-pool")

        @app.driver(emits=INT_SCHEMA, name="src")
        def src(ctx, n=40):
            return ({"value": i} for i in range(n))

        # two DEVICE stages -> one fused unit; min_instances folds to 2 via
        # the declared AU (synthetic combinator stages pin to 1, so use a
        # declared DEVICE AU chain)
        @app.analytics_unit(emits=INT_SCHEMA, placement=Placement.DEVICE,
                            min_instances=2, max_instances=4, name="inc")
        def inc(ctx):
            return lambda stream, payload: {"value": payload["value"] + 1}

        @app.analytics_unit(emits=INT_SCHEMA, placement=Placement.DEVICE,
                            min_instances=2, max_instances=4, name="dbl")
        def dbl(ctx):
            return lambda stream, payload: {"value": payload["value"] * 2}

        (app.sense("raw", src, n=40).via(inc, name="plus").via(dbl,
                                                               name="exit"))
        built = app.build()
        fused = [a for a in built.analytics_units if a.fused_stages]
        assert len(fused) == 1 and fused[0].min_instances == 2
        built.deploy(op, start_sensors=False)
        handles = op.executor.instances_of("exit")
        assert len(handles) == 2
        assert all(h.sidecar.group == "exit" for h in handles)
        sub = op.subscribe("exit")
        op.start_pending_sensors()
        vals = sorted(m.payload["value"] for m in drain(sub, 40))
        assert vals == sorted((i + 1) * 2 for i in range(40))  # exactly once
        g = op.bus.stats()["raw"]["groups"]["exit"]
        assert len(g["members"]) == 2 and g["delivered"] == 40
    finally:
        op.shutdown()


def test_dsl_scaled_group_pool_end_to_end():
    op = Operator(reconcile_interval_s=0.05)
    try:
        app = App("scaled-map")

        @app.driver(emits=INT_SCHEMA)
        def src(ctx, n=30):
            return ({"value": i} for i in range(n))

        (app.sense("raw", src, n=30)
            .map(lambda p: {"value": p["value"] + 1}, emits=INT_SCHEMA,
                 name="shifted")
            .scaled(instances=4))
        built = app.build()
        spec = next(s for s in built.streams if s.name == "shifted")
        assert spec.delivery == "group" and spec.fixed_instances == 4
        built.deploy(op, start_sensors=False)
        assert len(op.executor.instances_of("shifted")) == 4
        sub = op.subscribe("shifted")
        op.start_pending_sensors()
        vals = sorted(m.payload["value"] for m in drain(sub, 30))
        assert vals == list(range(1, 31))        # pool keeps exactly-once
        assert sub.next(timeout=0.3) is None
    finally:
        op.shutdown()


def test_dsl_scaled_rejections():
    app = App("bad-scaled")

    @app.driver(emits=INT_SCHEMA)
    def src(ctx, n=5):
        return ({"value": i} for i in range(n))

    raw = app.sense("raw", src)
    with pytest.raises(DSLError):
        raw.scaled(instances=2)                  # sensors don't scale
    mapped = raw.map(lambda p: p, name="m")
    with pytest.raises(DSLError):
        mapped.scaled(delivery="anycast")
    with pytest.raises(DSLError):
        mapped.scaled(instances=0)
    with pytest.raises(DSLError):
        mapped.scaled(max_instances=0)
    with pytest.raises(DSLError):
        mapped.scaled(delivery="broadcast", instances=2)  # duplicates output
    windowed = mapped.window(3, name="w")
    with pytest.raises(DSLError):
        windowed.scaled(instances=2)             # stateful combinator
    # group-scaling a stateless combinator is the supported path
    mapped.scaled(instances=2)
    spec = next(s for s in app._streams if s.name == "m")
    assert spec.fixed_instances == 2 and spec.delivery == "group"
    # the guard judges the RESULTING config: a later broadcast flip on an
    # already-scaled stage must be rejected too, not just broadcast+N in
    # one call
    with pytest.raises(DSLError):
        mapped.scaled(delivery="broadcast")
    mapped2 = raw.map(lambda p: p, name="m2")
    mapped2.scaled(max_instances=4)              # lifts the envelope
    with pytest.raises(DSLError):
        mapped2.scaled(delivery="broadcast")     # 4x duplication otherwise


def test_scaled_device_stage_is_a_fusion_barrier():
    """A fixed pool on a DEVICE stage survives build(): the stage stays
    unfused (fixed_instances > 1 is a segment barrier) with its pool size
    intact, rather than being folded and demoted."""
    app = App("scaled-device")

    @app.driver(emits=INT_SCHEMA)
    def src(ctx, n=5):
        return ({"value": i} for i in range(n))

    (app.sense("raw", src)
        .map(lambda p: {"value": p["value"] + 1}, emits=INT_SCHEMA,
             device=True, name="a")
        .map(lambda p: {"value": p["value"] * 2}, emits=INT_SCHEMA,
             device=True, name="b")
        .scaled(instances=4))
    built = app.build()
    spec = next(s for s in built.streams if s.name == "b")
    assert spec.fixed_instances == 4 and spec.delivery == "group"
    assert not any(a.fused_stages for a in built.analytics_units)


def test_dsl_scaled_autoscale_ceiling_lifts_combinator_envelope():
    app = App("autoscaled-map")

    @app.driver(emits=INT_SCHEMA)
    def src(ctx, n=5):
        return ({"value": i} for i in range(n))

    mapped = app.sense("raw", src).map(lambda p: p, name="m")
    mapped.scaled(max_instances=6)
    spec = next(s for s in app._streams if s.name == "m")
    assert spec.fixed_instances is None          # operator autoscales
    assert app._aus[spec.analytics_unit].max_instances == 6


# ---------------------------------------------------------------------------
# Autoscaler: group-aggregate backlog + drops as a hard signal
# ---------------------------------------------------------------------------

class _FakeSidecar:
    def __init__(self, backlog, idle=0.0, dropped=0):
        self._m = {"instance": f"fake-{id(self):x}", "backlog": backlog,
                   "idle_s": idle, "dropped": dropped}

    def metrics(self):
        return dict(self._m, received=0, published=0, processed=0,
                    errors=0, latency_ewma_s=0, uptime_s=1)


class _H:
    def __init__(self, backlog, idle=0.0, dropped=0):
        self.sidecar = _FakeSidecar(backlog, idle, dropped)


def test_autoscaler_uses_group_aggregate_backlog():
    scaler = AutoScaler(ScalePolicy(backlog_high=10, backlog_low=1,
                                    idle_s=0.0, cooldown_s=0.0))
    # pool of 2 with per-member backlog 8: aggregate 16 < 2*10 -> steady
    # (the old per-replica max would not have scaled either; the aggregate
    # form must not misread split mailboxes as idle capacity)
    assert scaler.decide("a", [_H(8), _H(8)], 1, 8) == 2
    # aggregate 30 > 2*10 -> scale up even though no single mailbox > high
    assert scaler.decide("b", [_H(15), _H(15)], 1, 8) == 4


def test_autoscaler_treats_drops_as_hard_scale_up():
    scaler = AutoScaler(ScalePolicy(backlog_high=100, backlog_low=1,
                                    idle_s=0.0, cooldown_s=0.0))
    h = _H(0, dropped=5)
    # zero backlog but the pool dropped messages -> scale up regardless
    assert scaler.decide("s", [h], 1, 8) == 2
    # unchanged drop counter on the next decision -> no further scale-up
    assert scaler.decide("s", [h], 1, 8) == 1
    # fresh drops -> scale up again
    h.sidecar._m["dropped"] = 9
    assert scaler.decide("s", [h], 1, 8) == 2
    # at the ceiling, drops cannot push past max_instances
    assert scaler.decide("s", [_H(0, dropped=12)], 1, 1) == 1


def test_autoscaler_drop_signal_survives_instance_replacement():
    """Watermarks are per-instance: replacing a high-drop member must not
    mask fresh drops on the survivors behind the old pool total."""
    scaler = AutoScaler(ScalePolicy(backlog_high=100, backlog_low=1,
                                    idle_s=0.0, cooldown_s=0.0))
    worst, ok = _H(0, dropped=10), _H(0, dropped=0)
    assert scaler.decide("s", [worst, ok], 1, 8) == 4
    # straggler pass replaced `worst`; survivor then drops 6 NEW messages —
    # a pool-total watermark (6 < 10) would swallow the signal
    fresh = _H(0, dropped=0)
    ok.sidecar._m["dropped"] = 6
    assert scaler.decide("s", [fresh, ok], 1, 8) == 4


def test_scaled_instances_share_work_under_load():
    """End-to-end: a grouped pool splits the message load across members."""
    op = _operator()
    try:
        op.register_sensor(SensorSpec(name="nums", driver="counter",
                                      config={"n": 40}), start=False)
        op.create_stream(StreamSpec(name="out", analytics_unit="ident",
                                    inputs=("nums",), fixed_instances=4))
        sub = op.subscribe("out")
        op.start_pending_sensors()
        drain(sub, 40)
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline:
            processed = [h.sidecar.processed
                         for h in op.executor.instances_of("out")]
            if sum(processed) == 40:
                break
            time.sleep(0.05)
        assert sum(processed) == 40              # each message exactly once…
        assert all(p > 0 for p in processed)     # …and every member worked
    finally:
        op.shutdown()
