"""Batched fused execution (tentpole PR 5) + fallback/poison bugfixes.

Contracts:
(a) bus: ``Subscription.next_batch`` pops up to max_n queued items in one
    lock acquisition — order preserved, group/keyed ``note_consumed``
    accounting intact, blocking only for the first item;
(b) sidecar: ``next_batch`` pulls a burst from ONE input subject and keeps
    batch-size metrics; executor drain-a-burst mode hands whole bursts to
    ``process_batch`` and degrades to the per-message path when shallow;
(c) fusion: batched execution is bit-identical to per-message execution and
    to the host chain (outputs, filter decisions, order) — property-tested
    across random chains, batch sizes and ragged tails; without jax the
    batch path cleanly degrades to the host chain;
(d) bugfix: one bad payload falls back for THAT message only (device mode
    stays live, ``device_fallbacks`` counted in sidecar metrics); a genuine
    trace failure still demotes permanently;
(e) bugfix: a poison message crashing an instance lands on the subject's
    ``lost`` stat, and reap -> ``depart()`` re-homes the crashed member's
    remaining mailbox backlog to group survivors.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (AnalyticsUnitSpec, App, ConfigSchema, DriverSpec,
                        DSLError, Executor, FieldSpec, MessageBus, Operator,
                        OperatorError, SensorSpec, Sidecar, StreamSchema,
                        StreamSpec, connect, drain)
from repro.core import fusion
from repro.core.fusion import FusedStage, make_fused_logic
from repro.core.sdk import LogicContext

INT_SCHEMA = StreamSchema.of(value=FieldSpec("int"))
TEN = StreamSchema.device(x=((8, 8), "float32"))


# ---------------------------------------------------------------------------
# (a) bus: Subscription.next_batch
# ---------------------------------------------------------------------------

@pytest.fixture
def bus():
    b = MessageBus()
    b.register_subject("s", INT_SCHEMA)
    return b


def test_next_batch_orders_and_bounds(bus):
    tok = bus.issue_token("t", ["s"])
    sub = bus.subscribe("s", token=tok)
    for i in range(7):
        bus.publish("s", {"value": i}, token=tok)
    assert [m.payload["value"] for m in sub.next_batch(5, timeout=0)] == \
        [0, 1, 2, 3, 4]
    assert [m.payload["value"] for m in sub.next_batch(5, timeout=0)] == \
        [5, 6]
    assert sub.next_batch(5, timeout=0) == []
    assert sub.qsize() == 0


def test_next_batch_blocks_for_first_item_only(bus):
    tok = bus.issue_token("t", ["s"])
    sub = bus.subscribe("s", token=tok)
    t0 = time.monotonic()
    assert sub.next_batch(4, timeout=0.05) == []     # timeout, not hang
    assert time.monotonic() - t0 < 2.0
    bus.publish("s", {"value": 0}, token=tok)
    # one queued item -> a 1-message burst; no waiting for more to arrive
    assert [m.payload["value"] for m in sub.next_batch(4, timeout=5)] == [0]


def test_next_batch_stops_at_close_sentinel(bus):
    tok = bus.issue_token("t", ["s"])
    sub = bus.subscribe("s", token=tok)
    for i in range(2):
        bus.publish("s", {"value": i}, token=tok)
    sub.close()                                      # sentinel lands last
    assert [m.payload["value"] for m in sub.next_batch(10, timeout=0)] == \
        [0, 1]
    assert sub.next_batch(10, timeout=0.01) == []


def test_next_batch_decodes_wire_subscriptions(bus):
    tok = bus.issue_token("t", ["s"])
    sub = bus.subscribe("s", token=tok, wire=True)
    for i in range(3):
        bus.publish("s", {"value": i}, token=tok)
    batch = sub.next_batch(3, timeout=0)
    assert [m.payload["value"] for m in batch] == [0, 1, 2]
    assert all(m.subject == "s" for m in batch)


def test_next_batch_keeps_keyed_partition_accounting(bus):
    tok = bus.issue_token("t", ["s"])
    sub = bus.subscribe("s", token=tok, group="pool", key="value", name="m0")
    for i in range(6):
        bus.publish("s", {"value": i}, token=tok)
    before = bus.group_info("s", "pool")["partition_backlog"]
    assert sum(before.values()) == 6
    got = sub.next_batch(6, timeout=0)
    assert [m.payload["value"] for m in got] == list(range(6))
    # every popped item was note_consumed: exact backlog reaches zero
    assert bus.group_info("s", "pool")["partition_backlog"] == {}


# ---------------------------------------------------------------------------
# (b) sidecar burst pull + executor drain-a-burst mode
# ---------------------------------------------------------------------------

def test_sidecar_next_batch_records_burst_metrics():
    bus_ = MessageBus()
    bus_.register_subject("in", INT_SCHEMA)
    sc = Sidecar("i", bus_, inputs=("in",))
    tok = bus_.issue_token("pub", ["in"])
    for i in range(5):
        bus_.publish("in", {"value": i}, token=tok)
    stream, msgs = sc.next_batch(4, timeout=1)
    assert stream == "in" and [m.payload["value"] for m in msgs] == \
        [0, 1, 2, 3]
    stream, msgs = sc.next_batch(4, timeout=1)
    assert [m.payload["value"] for m in msgs] == [4]
    m = sc.metrics()
    assert (m["batches"], m["batch_msgs"], m["max_batch_seen"]) == (2, 5, 4)
    assert m["avg_batch"] == 2.5
    sc.close()
    bus_.close()


def test_pump_hands_bursts_to_process_batch():
    """A batching-capable process sees the queued backlog as bursts, with
    per-message emission order preserved (None = filtered)."""
    bus_ = MessageBus()
    bus_.register_subject("in", INT_SCHEMA)
    bus_.register_subject("out", INT_SCHEMA)
    ex = Executor(bus_)
    bursts = []

    def logic(ctx):
        def process(stream, payload):
            return {"value": payload["value"]}

        def process_batch(stream, payloads):
            bursts.append(len(payloads))
            return [None if p["value"] % 3 == 0 else {"value": p["value"]}
                    for p in payloads]
        process.process_batch = process_batch
        process.default_max_batch = 8
        return process

    tok = bus_.issue_token("pub", ["in"])
    out = bus_.subscribe("out", token=bus_.issue_token("ext", ["out"]))
    # preload the mailbox, then start the instance: the first pull sees a
    # deep mailbox and must drain it as bursts of <= 8
    sc = Sidecar("pre", bus_, inputs=("in",), output="out", group="w")
    for i in range(1, 20):
        bus_.publish("in", {"value": i}, token=tok)
    stop = threading.Event()
    t = threading.Thread(
        target=lambda: Executor._pump(logic(LogicContext({})), sc, stop,
                                      sink=False), daemon=True)
    t.start()
    expect = [i for i in range(1, 20) if i % 3 != 0]
    got = [m.payload["value"] for m in drain(out, len(expect), timeout=10)]
    stop.set()
    t.join(timeout=5)
    assert got == expect                      # order preserved, filters honored
    assert bursts and max(bursts) > 1         # batching actually engaged
    assert all(b <= 8 for b in bursts)
    sc.close()
    bus_.close()


# ---------------------------------------------------------------------------
# (c) batched == per-message == host chain (property-tested)
# ---------------------------------------------------------------------------

def _stage(kind, fn):
    if kind == "filter":
        factory = lambda ctx: (lambda s, p: p if fn(p) else None)  # noqa: E731
    else:
        factory = lambda ctx: (lambda s, p: fn(p))                 # noqa: E731
    return FusedStage(au_name=f"{kind}au", stream_name="st",
                      factory=factory, config={}, kind=kind, pure_fn=fn)


def _proc(stages, max_batch=None):
    return make_fused_logic(stages, None, max_batch=max_batch)(
        LogicContext({}))


_OPS = [
    ("map", lambda p: {"x": p["x"] * 2}),
    ("map", lambda p: {"x": p["x"] + 1}),
    ("map", lambda p: {"x": -p["x"]}),
    ("map", lambda p: {"x": p["x"], "s": p["x"].sum()}),
    ("filter", lambda p: p["x"][0] < 3),
    ("filter", lambda p: p["x"].sum() > -20),
]


def _assert_same_results(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        if ra is None or rb is None:
            assert ra is None and rb is None   # same filter decisions
            continue
        assert ra.keys() == rb.keys()
        for k in ra:
            va, vb = np.asarray(ra[k]), np.asarray(rb[k])
            assert va.dtype == vb.dtype, k
            assert np.array_equal(va, vb), k
        for k in ra:                            # scalar typing parity
            assert type(ra[k]) is type(rb[k]), k


try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except Exception:  # pragma: no cover - minimal-deps CI leg
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    _chains = st.lists(st.sampled_from(range(len(_OPS))), min_size=1,
                       max_size=4)

    @settings(max_examples=20, deadline=None)
    @given(_chains, st.integers(1, 9), st.integers(1, 4), st.booleans(),
           st.data())
    def test_batched_bit_identical_to_per_message(chain, batch, width,
                                                  ragged, data):
        """Across random chains, batch sizes and ragged tails: batched
        execution produces the same outputs, the same filter decisions, in
        the same order as per-message execution and as the host chain."""
        stages = [_stage(*_OPS[i]) for i in chain]
        payloads = []
        for b in range(batch):
            w = data.draw(st.integers(1, 4)) if ragged else width
            vals = data.draw(st.lists(st.integers(-5, 5), min_size=w,
                                      max_size=w))
            payloads.append({"x": np.asarray(vals, np.float32)})
        host = _proc([_stage(*_OPS[i]) for i in chain])
        expected = [host("s", dict(p)) for p in payloads]
        if fusion.jax_available():
            import os
            old = os.environ.get("DATAX_FUSION_JIT")
            os.environ["DATAX_FUSION_JIT"] = "always"
            try:
                dev_batched = _proc(stages, max_batch=batch)
                got = dev_batched.process_batch("s", [dict(p)
                                                      for p in payloads])
                _assert_same_results(got, expected)
                dev_single = _proc([_stage(*_OPS[i]) for i in chain])
                singles = [dev_single("s", dict(p)) for p in payloads]
                _assert_same_results(singles, expected)
            finally:
                if old is None:
                    del os.environ["DATAX_FUSION_JIT"]
                else:
                    os.environ["DATAX_FUSION_JIT"] = old
        else:
            got = host.process_batch("s", [dict(p) for p in payloads])
            _assert_same_results(got, expected)


def test_batch_path_degrades_to_host_chain_without_jax(monkeypatch):
    """The jax-free leg: process_batch exists, runs the host chain
    per message, and never claims a batched device burst."""
    monkeypatch.setattr(fusion, "_HAS_JAX", False)
    stages = [_stage(*_OPS[0]), _stage(*_OPS[4])]
    proc = _proc(stages, max_batch=8)
    payloads = [{"x": np.asarray([v, v], np.float32)} for v in range(5)]
    got = proc.process_batch("s", [dict(p) for p in payloads])
    expected = [proc("s", dict(p)) for p in payloads]
    _assert_same_results(got, expected)
    assert proc.stats["batched_bursts"] == 0
    assert proc.stats["device_fallbacks"] == 0


def test_batched_execution_end_to_end_ordered(monkeypatch):
    """Deployed fused unit with .scaled(max_batch=): outputs arrive in exact
    per-message order, bit-identical to the unfused bus run, and the sidecar
    shows bursts deeper than one message."""
    if not fusion.jax_available():
        pytest.skip("end-to-end batched device path needs jax")
    monkeypatch.setenv("DATAX_FUSION_JIT", "always")

    def build():
        app = App("batched")

        @app.driver(emits=TEN)
        def src(ctx, n=40):
            return ({"x": np.full((8, 8), float(i), np.float32)}
                    for i in range(n))

        (app.sense("raw", src, n=40)
            .map(lambda p: {"x": p["x"] * 2}, emits=TEN, device=True,
                 name="m1")
            .filter(lambda p: p["x"][0, 0] < 60.0, device=True, name="f1")
            .map(lambda p: {"x": p["x"] + 1}, emits=TEN, device=True,
                 name="exit")
            .scaled(max_batch=8))
        return app

    def run(fuse):
        with connect(start=False) as op:
            build().deploy(op, start_sensors=False, fuse=fuse)
            sub = op.subscribe("exit", maxsize=64)
            op.start_pending_sensors()
            out = [m.payload for m in drain(sub, 30, timeout=30)]
            handles = op.executor.instances_of("exit")
            metrics = handles[0].sidecar.metrics() if handles else {}
            return out, metrics

    fused, m = run(True)
    unfused, _ = run(False)
    assert len(fused) == len(unfused) == 30
    for pa, pb in zip(fused, unfused):       # exact order + bit-identity
        assert np.array_equal(pa["x"], pb["x"])
        assert np.asarray(pa["x"]).dtype == np.asarray(pb["x"]).dtype
    assert m["max_batch_seen"] > 1           # bursts actually happened
    assert m["batch_msgs"] == 40             # every input message, batched
    assert m["batched_bursts"] > 0           # the vmapped program really ran
    assert m["device_fallbacks"] == 0


# ---------------------------------------------------------------------------
# (d) bugfix: payload fallback is per-message, not a permanent demotion
# ---------------------------------------------------------------------------

def test_bad_payload_falls_back_per_message_keeps_device_mode(monkeypatch):
    if not fusion.jax_available():
        pytest.skip("device-mode fallback accounting needs jax")
    monkeypatch.delenv("DATAX_FUSION_JIT", raising=False)
    monkeypatch.setattr(fusion, "JIT_MODE", "always")
    proc = _proc([_stage("map", lambda p: {"x": p["x"] * 2})], max_batch=4)
    good = {"x": np.arange(4, dtype=np.float32)}
    assert np.array_equal(proc("s", dict(good))["x"], good["x"] * 2)
    # a single non-numeric payload: host chain for THIS message only
    assert proc("s", {"x": "bad"}) == {"x": "badbad"}
    assert proc.stats["device_fallbacks"] == 1
    # conversion failures that are NOT TypeError (an oversized python int
    # overflows jnp.asarray) are payload problems too — same fallback
    assert proc("s", {"x": 2 ** 80}) == {"x": 2 ** 81}
    assert proc.stats["device_fallbacks"] == 2
    # the device program is still live: the next burst runs batched
    out = proc.process_batch("s", [dict(good), dict(good)])
    assert proc.stats["batched_bursts"] == 1
    assert all(np.array_equal(o["x"], good["x"] * 2) for o in out)


def test_trace_failure_still_demotes_permanently(monkeypatch):
    if not fusion.jax_available():
        pytest.skip("trace-failure demotion needs jax")
    monkeypatch.delenv("DATAX_FUSION_JIT", raising=False)
    monkeypatch.setattr(fusion, "JIT_MODE", "always")
    # float(tracer) raises under jit: an impure stage, not a payload problem
    impure = lambda p: {"x": p["x"] * (2.0 if float(p["x"].sum()) >= 0  # noqa: E731
                                       else 1.0)}
    proc = _proc([_stage("map", impure)], max_batch=4)
    good = {"x": np.arange(4, dtype=np.float32)}
    assert np.array_equal(proc("s", dict(good))["x"], good["x"] * 2.0)
    out = proc.process_batch("s", [dict(good), dict(good)])
    assert all(np.array_equal(o["x"], good["x"] * 2.0) for o in out)
    assert proc.stats["batched_bursts"] == 0      # demoted: host chain now
    assert proc.stats["device_fallbacks"] == 0    # not a payload fallback


def test_ragged_burst_degrades_per_message_and_stays_device(monkeypatch):
    if not fusion.jax_available():
        pytest.skip("ragged-burst degradation needs jax")
    monkeypatch.delenv("DATAX_FUSION_JIT", raising=False)
    monkeypatch.setattr(fusion, "JIT_MODE", "always")
    proc = _proc([_stage("map", lambda p: {"x": p["x"] * 2})], max_batch=4)
    ragged = [{"x": np.arange(n, dtype=np.float32)} for n in (2, 3, 2)]
    out = proc.process_batch("s", [dict(p) for p in ragged])
    for o, p in zip(out, ragged):
        assert np.array_equal(o["x"], p["x"] * 2)
    assert proc.stats["unstackable_bursts"] == 1  # the burst degraded …
    assert proc.stats["device_fallbacks"] == 0    # … but stayed on-device
    # stackable bursts afterwards still run batched
    uniform = [{"x": np.arange(3, dtype=np.float32)}] * 2
    proc.process_batch("s", [dict(p) for p in uniform])
    assert proc.stats["batched_bursts"] == 1


def test_device_fallbacks_surface_in_sidecar_metrics(monkeypatch):
    if not fusion.jax_available():
        pytest.skip("device fallback metrics need jax")
    monkeypatch.setenv("DATAX_FUSION_JIT", "always")
    app = App("fallback-metrics")

    @app.driver()  # untyped: lets a non-numeric payload through
    def src(ctx, n=4):
        def gen():
            for i in range(n):
                yield ({"x": "bad"} if i == 1
                       else {"x": np.full((4,), float(i), np.float32)})
        return gen()

    (app.sense("raw", src)
        .map(lambda p: {"x": p["x"] * 2}, device=True, name="m1")
        .map(lambda p: {"x": p["x"] * 1}, device=True, name="exit"))
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        sub = op.subscribe("exit", maxsize=16)
        op.start_pending_sensors()
        out = [m.payload for m in drain(sub, 4, timeout=30)]
        metrics = op.executor.instances_of("exit")[0].sidecar.metrics()
    assert out[1]["x"] == "badbad"                # host chain result
    assert np.array_equal(out[2]["x"], np.full((4,), 4.0, np.float32))
    assert metrics["device_fallbacks"] == 1       # exposed on the sidecar


# ---------------------------------------------------------------------------
# (e) bugfix: poison messages are accounted and backlog re-homed
# ---------------------------------------------------------------------------

def _poison_executor():
    bus_ = MessageBus()
    bus_.register_subject("in", INT_SCHEMA)
    bus_.register_subject("out", INT_SCHEMA)
    ex = Executor(bus_)

    def logic(ctx):
        def process(stream, payload):
            if payload["value"] < 0:
                raise RuntimeError("poison")
            return {"value": payload["value"]}
        return process

    return bus_, ex, logic


def test_poison_message_lands_on_subject_lost_stat():
    bus_, ex, logic = _poison_executor()
    try:
        h = ex.start_instance(entity_kind="analytics_unit", entity_name="au",
                              owner="w", logic=logic, config={},
                              inputs=("in",), output="out", group="w")
        tok = bus_.issue_token("pub", ["in"])
        bus_.publish("in", {"value": -1}, token=tok)
        h.thread.join(timeout=10)
        assert h.crashed
        # the popped copy was the only one — it must not vanish uncounted
        assert bus_.stats()["in"]["lost"] == 1
    finally:
        ex.shutdown()
        bus_.close()


def test_poison_burst_counts_every_inflight_message():
    bus_ = MessageBus()
    bus_.register_subject("in", INT_SCHEMA)
    sc = Sidecar("i", bus_, inputs=("in",))
    tok = bus_.issue_token("pub", ["in"])
    for i in range(4):
        bus_.publish("in", {"value": i}, token=tok)

    def process(stream, payload):
        raise RuntimeError("poison")

    def process_batch(stream, payloads):
        raise RuntimeError("poison burst")
    process.process_batch = process_batch
    process.default_max_batch = 8
    with pytest.raises(RuntimeError):
        Executor._pump(process, sc, threading.Event(), sink=False)
    assert bus_.stats()["in"]["lost"] == 4
    sc.close()
    bus_.close()


def test_poison_mid_burst_emits_prefix_and_counts_only_tail(monkeypatch):
    """A poison message partway through a burst must not destroy its
    already-processed predecessors: the fused unit's per-message fallback
    hands the successful prefix back (BatchInterrupted), the pump emits it,
    and only the poison + unprocessed tail count as lost."""
    monkeypatch.setattr(fusion, "_HAS_JAX", False)   # host-chain burst mode

    def boom_factory(ctx):
        def proc(stream, payload):
            if payload["value"] < 0:
                raise RuntimeError("poison")
            return {"value": payload["value"] * 2}
        return proc

    stages = [FusedStage(au_name="au", stream_name="st",
                         factory=boom_factory, config={}, kind="au",
                         pure_fn=None)]
    proc = make_fused_logic(stages, None, max_batch=8)(LogicContext({}))
    bus_ = MessageBus()
    bus_.register_subject("in", INT_SCHEMA)
    bus_.register_subject("out", INT_SCHEMA)
    sc = Sidecar("i", bus_, inputs=("in",), output="out")
    out = bus_.subscribe("out", token=bus_.issue_token("ext", ["out"]))
    tok = bus_.issue_token("pub", ["in"])
    for v in (1, 2, -1, 4, 5):
        bus_.publish("in", {"value": v}, token=tok)
    from repro.core import BatchInterrupted
    with pytest.raises(BatchInterrupted):
        Executor._pump(proc, sc, threading.Event(), sink=False)
    # prefix flowed downstream before the crash …
    assert [m.payload["value"] for m in drain(out, 2, timeout=5)] == [2, 4]
    # … and only the poison and the unprocessed tail are lost
    assert bus_.stats()["in"]["lost"] == 3
    sc.close()
    bus_.close()


def test_reap_rehomes_crashed_members_backlog_to_survivors():
    """Regression: reap -> depart() hands the crashed member's remaining
    mailbox backlog to the group survivors; only the poison message is lost,
    and it is counted."""
    bus_, ex, logic = _poison_executor()
    try:
        a = ex.start_instance(entity_kind="analytics_unit", entity_name="au",
                              owner="w", logic=logic, config={},
                              inputs=("in",), output="out", group="w")
        ex.start_instance(entity_kind="analytics_unit", entity_name="au",
                          owner="w", logic=logic, config={},
                          inputs=("in",), output="out", group="w")
        out = bus_.subscribe("out", token=bus_.issue_token("ext", ["out"]),
                             maxsize=64)
        tok = bus_.issue_token("pub", ["in"])
        # round-robin cursor starts at the first member: the poison goes to a
        bus_.publish("in", {"value": -1}, token=tok)
        a.thread.join(timeout=10)
        assert a.crashed
        # a is dead but not yet reaped: round-robin still deals it a share,
        # which queues in its mailbox with nobody left to drain it
        for i in range(20):
            bus_.publish("in", {"value": i}, token=tok)
        dead = ex.reap_dead()
        assert [h.instance_id for h in dead] == [a.instance_id]
        vals = sorted(m.payload["value"] for m in drain(out, 20, timeout=10))
        assert vals == list(range(20))           # nothing lost but the poison
        st = bus_.stats()["in"]
        assert st["lost"] == 1                   # the poison, counted
        assert st["groups"]["w"]["rerouted"] > 0  # backlog re-homed, not lost
    finally:
        ex.shutdown()
        bus_.close()


def test_reconciler_restarts_poisoned_instance_and_stream_recovers():
    """End to end: poison crashes the only instance, the loss is counted,
    the reconciler restarts it, and the stream keeps flowing."""
    op = Operator(reconcile_interval_s=0.05)
    try:
        op.register_driver(DriverSpec(
            name="quiet", logic=lambda ctx: iter(()),
            output_schema=INT_SCHEMA))
        op.register_analytics_unit(AnalyticsUnitSpec(
            name="fragile",
            logic=lambda ctx: (lambda s, p:
                               (_ for _ in ()).throw(RuntimeError("poison"))
                               if p["value"] < 0 else {"value": p["value"]}),
            output_schema=INT_SCHEMA))
        op.register_sensor(SensorSpec(name="nums", driver="quiet"),
                           start=False)
        op.create_stream(StreamSpec(name="outs", analytics_unit="fragile",
                                    inputs=("nums",), fixed_instances=1))
        op.start()
        sub = op.subscribe("outs")
        tok = op.bus.issue_token("pub", ["nums"])
        op.bus.publish("nums", {"value": -1}, token=tok)     # poison
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            if any(k == "restart" for _, k, _d in op.events):
                break
            time.sleep(0.02)
        assert any(k == "restart" for _, k, _d in op.events)
        op.bus.publish("nums", {"value": 7}, token=tok)      # flows again
        assert drain(sub, 1, timeout=10)[0].payload["value"] == 7
        assert op.bus.stats()["nums"]["lost"] == 1
    finally:
        op.shutdown()


# ---------------------------------------------------------------------------
# plumbing: DSL .scaled(max_batch=) -> StreamSpec -> fused unit
# ---------------------------------------------------------------------------

def _device_chain_app(max_batch=None, on="exit", mid_batch=None):
    app = App("knob")

    @app.driver(emits=TEN)
    def src(ctx):
        return iter(())

    h1 = app.sense("raw", src).map(lambda p: p, emits=TEN, device=True,
                                   name="mid")
    h2 = h1.map(lambda p: p, emits=TEN, device=True, name="exit")
    if mid_batch is not None:
        h1.scaled(max_batch=mid_batch)
    if max_batch is not None:
        (h1 if on == "mid" else h2).scaled(max_batch=max_batch)
    return app


def test_scaled_max_batch_reaches_fused_stream_spec():
    built = _device_chain_app(max_batch=16).build()
    assert built.streams[0].max_batch == 16
    # declared on an INTERIOR stage: fusion folds it onto the fused unit
    built = _device_chain_app(max_batch=4, on="mid").build()
    assert built.streams[0].max_batch == 4
    # no knob -> platform default applies at the unit, spec stays None
    assert _device_chain_app().build().streams[0].max_batch is None
    # conflicting declarations: the stage closest to the exit wins, so a
    # trailing max_batch=1 really does force per-message dispatch
    built = _device_chain_app(max_batch=1, mid_batch=32).build()
    assert built.streams[0].max_batch == 1


def test_scaled_max_batch_validation():
    with pytest.raises(DSLError):
        _device_chain_app(max_batch=0)


def test_operator_rejects_bad_max_batch():
    op = Operator()
    try:
        op.register_driver(DriverSpec(
            name="counter", logic=lambda ctx: iter(()),
            config_schema=ConfigSchema.empty(), output_schema=INT_SCHEMA))
        op.register_analytics_unit(AnalyticsUnitSpec(
            name="ident", logic=lambda ctx: (lambda s, p: p),
            output_schema=INT_SCHEMA))
        op.register_sensor(SensorSpec(name="nums", driver="counter"),
                           start=False)
        with pytest.raises(OperatorError):
            op.create_stream(StreamSpec(name="out", analytics_unit="ident",
                                        inputs=("nums",), max_batch=0))
    finally:
        op.shutdown()
