"""Platform state management: StateStore engines, persistence, DB-in-AU."""

import pytest

from repro.core import (AnalyticsUnitSpec, DriverSpec, FieldSpec, Operator,
                        SensorSpec, StateError, StateStore, StreamSchema,
                        StreamSpec, drain)


def test_memkv_tables():
    store = StateStore()
    db = store.create("app", tables={"users": ["name", "score"]})
    t = db.table("users")
    t.put(1, {"name": "a", "score": 10})
    t.put(2, {"name": "b", "score": 20})
    assert t.get(1)["name"] == "a"
    t.update(1, score=15)
    assert t.get(1)["score"] == 15
    assert len(t.scan(lambda k, v: v["score"] > 12)) == 2
    with pytest.raises(StateError):
        t.put(3, {"bogus_column": 1})
    t.delete(2)
    assert t.get(2) is None


def test_filekv_persistence(tmp_path):
    store = StateStore(root=str(tmp_path))
    db = store.create("p", engine="filekv", tables={"kv": None})
    db.table("kv").put("alpha", {"v": 42})
    db.flush()
    # simulate restart
    store2 = StateStore(root=str(tmp_path))
    db2 = store2.create("p", engine="filekv")
    assert db2.table("kv").get("alpha")["v"] == 42


def test_duplicate_database_refused():
    store = StateStore()
    store.create("x")
    with pytest.raises(StateError):
        store.create("x")


def test_stateful_au_gets_platform_db():
    """Paper §2: platform installs the DB; the app manages content."""
    op = Operator(reconcile_interval_s=0.05)

    def src(ctx):
        def gen():
            for i in range(10):
                yield {"value": i}
        return gen()

    def accumulating_au(ctx):
        table = ctx.db.ensure_table("seen")

        def process(stream, payload):
            table.put(payload["value"], {"seen": True})
            return {"value": len(table)}
        return process

    schema = StreamSchema.of(value=FieldSpec("int"))
    op.register_driver(DriverSpec(name="src", logic=src,
                                  output_schema=schema))
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="acc", logic=accumulating_au, output_schema=schema,
        stateful=True))
    op.register_sensor(SensorSpec(name="nums", driver="src"), start=False)
    op.create_stream(StreamSpec(name="counts", analytics_unit="acc",
                                inputs=("nums",)))
    sub = op.subscribe("counts")
    op.start_pending_sensors()
    vals = [m.payload["value"] for m in drain(sub, 10)]
    assert max(vals) == 10                      # all rows landed in the DB
    assert op.store.exists("au-counts")         # platform-installed database
    op.shutdown()
