"""Claim (tentpole PR 3): queue-group delivery makes auto-scaling add capacity.

Before queue groups, every instance of a scaled stream held its own bus
subscription and ``_deliver`` fanned each message out to all of them — scaling
N instances did N× the work, not 1/N of it.  With ``delivery="group"`` (the
platform default) the instances form a single-delivery worker pool, so the
same 4-stage pipeline should run ≈N× faster with N instances per stage.

The pipeline is service-time bound: each stage sleeps a fixed per-message
service time (the host-thread analog of an I/O or device-RPC bound stage,
and deliberately GIL-free so thread workers can actually overlap).  The same
topology is deployed twice, every stage at 1 instance and at ``WORKERS``
grouped instances; metric is end-to-end messages/s from sensor start to the
last exit message, best of ``RUNS``.

``run()`` returns the variant->metric dict that ``benchmarks.run`` writes to
``BENCH_scaling.json``; CI gates on ``speedup`` (grouped workers over single)
>= 2.  Group delivery is pure platform code — the gate runs on BOTH CI matrix
legs (no jax required).
"""
from __future__ import annotations

import time

from repro.core import App, FieldSpec, StreamSchema, connect, drain

from .common import emit

VALUE = StreamSchema.of(value=FieldSpec("int"))
# keep the burst strictly under the per-instance mailbox size (256) so both
# variants are lossless and the drain count is exact
FRAMES = 120
STAGES = 4
WORKERS = 4
SERVICE_S = 0.002   # per-message service time per stage
RUNS = 3            # best-of, to keep the CI gate robust to scheduler noise


def _app(instances: int, frames: int):
    app = App(f"scaling-bench-{instances}")

    @app.driver(emits=VALUE)
    def source(ctx, frames=FRAMES):
        return ({"value": i} for i in range(frames))

    @app.analytics_unit(expects=(VALUE,), emits=VALUE,
                        max_instances=max(WORKERS, 8))
    def work(ctx, service_s=SERVICE_S):
        def process(stream, payload):
            time.sleep(service_s)
            return {"value": payload["value"]}
        return process

    handle = app.sense("ingest", source, frames=frames)
    for i in range(STAGES):
        handle = handle.via(work, name=f"stage{i}",
                            fixed_instances=instances)
    return app, handle.name


def _measure(instances: int, frames: int = FRAMES) -> tuple[float, int, int]:
    """Deploy, push ``frames`` messages through, return
    (messages/s, total drops, exit-group member count)."""
    app, tail = _app(instances, frames)
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        sub = op.subscribe(tail, maxsize=frames + 8)
        time.sleep(0.2)  # let the worker threads boot
        t0 = time.perf_counter()
        op.start_pending_sensors()
        got = len(drain(sub, frames, timeout=120))
        dt = time.perf_counter() - t0
        stats = op.bus.stats()
        drops = sum(s["dropped"] for s in stats.values())
        members = len(stats[f"stage{STAGES - 2}"]["groups"]
                      .get(tail, {}).get("members", ()))
    return got / dt, drops, members


def run() -> dict:
    single, pooled = 0.0, 0.0
    drops = 0
    members = 0
    for _ in range(RUNS):
        rate, d, _ = _measure(1)
        single = max(single, rate)
        drops += d
        rate, d, members = _measure(WORKERS)
        pooled = max(pooled, rate)
        drops += d
    speedup = pooled / single
    emit("scaling_grouped_1", 1e6 / single, f"msgs_per_s={single:.0f}")
    emit(f"scaling_grouped_{WORKERS}", 1e6 / pooled,
         f"msgs_per_s={pooled:.0f}")
    emit("scaling_speedup", 0.0,
         f"{WORKERS}_workers_over_1={speedup:.2f}x")
    return {
        "grouped_1_msgs_per_s": round(single, 1),
        f"grouped_{WORKERS}_msgs_per_s": round(pooled, 1),
        "speedup": round(speedup, 3),
        "frames": FRAMES,
        "stages": STAGES,
        "workers": WORKERS,
        "service_time_s": SERVICE_S,
        "exit_group_members": members,
        "dropped": drops,
    }
