"""Claim (tentpole PR 3): queue-group delivery makes auto-scaling add capacity.

Before queue groups, every instance of a scaled stream held its own bus
subscription and ``_deliver`` fanned each message out to all of them — scaling
N instances did N× the work, not 1/N of it.  With ``delivery="group"`` (the
platform default) the instances form a single-delivery worker pool, so the
same 4-stage pipeline should run ≈N× faster with N instances per stage.

The pipeline is service-time bound: each stage sleeps a fixed per-message
service time (the host-thread analog of an I/O or device-RPC bound stage,
and deliberately GIL-free so thread workers can actually overlap).  The same
topology is deployed twice, every stage at 1 instance and at ``WORKERS``
grouped instances; metric is end-to-end messages/s from sensor start to the
last exit message, best of ``RUNS``.

PR 9 adds the **stealing** variant: a keyed pool with one straggler member
(8× the service time).  Keys pin work to members, so without stealing the
straggler's partitions queue behind it while its peers sit idle; with
pull-based work stealing (``MessageBus.enable_stealing``) idle members take
whole queued partitions from the deepest mailbox.  Gate: stealing >= 1.5×
no-stealing at the same skew, with 0 per-key ordering violations and
``stolen > 0`` (the steal path actually ran).

``run()`` returns the variant->metric dict that ``benchmarks.run`` writes to
``BENCH_scaling.json``; CI gates on ``speedup`` (grouped workers over single)
>= 2 and on the stealing variant above.  Group delivery is pure platform
code — the gates run on BOTH CI matrix legs (no jax required).
"""
from __future__ import annotations

import threading
import time

from repro.core import App, FieldSpec, StreamSchema, connect, drain

from .common import emit

VALUE = StreamSchema.of(value=FieldSpec("int"))
# keep the burst strictly under the per-instance mailbox size (256) so both
# variants are lossless and the drain count is exact
FRAMES = 120
STAGES = 4
WORKERS = 4
SERVICE_S = 0.002   # per-message service time per stage
RUNS = 3            # best-of, to keep the CI gate robust to scheduler noise

# -- stealing variant (PR 9) --------------------------------------------------
EVENT = StreamSchema.of(key=FieldSpec("str"), seq=FieldSpec("int"))
STEAL_KEYS = 64          # one key per ring slot -> near-uniform member load,
                         # so the straggler always holds a meaningful share
STEAL_ROUNDS = 4         # 256 messages total, straggler backlog < mailbox
SKEW_FAST_S = 0.002      # healthy member service time
SKEW_SLOW_S = 0.020      # the straggler: 10x slower per message
STEAL_RUNS = 2           # best PAIRED ratio (ring assignment varies per run)


def _app(instances: int, frames: int):
    app = App(f"scaling-bench-{instances}")

    @app.driver(emits=VALUE)
    def source(ctx, frames=FRAMES):
        return ({"value": i} for i in range(frames))

    @app.analytics_unit(expects=(VALUE,), emits=VALUE,
                        max_instances=max(WORKERS, 8))
    def work(ctx, service_s=SERVICE_S):
        def process(stream, payload):
            time.sleep(service_s)
            return {"value": payload["value"]}
        return process

    handle = app.sense("ingest", source, frames=frames)
    for i in range(STAGES):
        handle = handle.via(work, name=f"stage{i}",
                            fixed_instances=instances)
    return app, handle.name


def _measure(instances: int, frames: int = FRAMES) -> tuple[float, int, int]:
    """Deploy, push ``frames`` messages through, return
    (messages/s, total drops, exit-group member count)."""
    app, tail = _app(instances, frames)
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        sub = op.subscribe(tail, maxsize=frames + 8)
        time.sleep(0.2)  # let the worker threads boot
        t0 = time.perf_counter()
        op.start_pending_sensors()
        got = len(drain(sub, frames, timeout=120))
        dt = time.perf_counter() - t0
        stats = op.bus.stats()
        drops = sum(s["dropped"] for s in stats.values())
        members = len(stats[f"stage{STAGES - 2}"]["groups"]
                      .get(tail, {}).get("members", ()))
    return got / dt, drops, members


def _steal_app():
    """Keyed fold pool with ONE straggler member: the first worker thread to
    run the fold claims the straggler role and serves every later message at
    ``SKEW_SLOW_S`` (its peers at ``SKEW_FAST_S``).  Key->member pinning is
    what makes the straggler hurt: its partitions' backlog can only drain
    through it — unless the pool steals."""
    app = App("steal-bench")

    @app.driver(emits=EVENT)
    def source(ctx, rounds=STEAL_ROUNDS):
        def gen():
            for r in range(rounds):
                for k in range(STEAL_KEYS):
                    yield {"key": f"key-{k:02d}", "seq": r}
        return gen()

    straggler: dict = {"ident": None}
    claim = threading.Lock()

    def fold(acc, payload):
        me = threading.get_ident()
        if straggler["ident"] is None:
            with claim:
                if straggler["ident"] is None:
                    straggler["ident"] = me
        time.sleep(SKEW_SLOW_S if straggler["ident"] == me else SKEW_FAST_S)
        n = (acc or {"n": 0})["n"]
        return {"n": n + 1, "seq": payload["seq"]}

    (app.sense("sevents", source)
        .key_by("key")
        .reduce(fold, name="scounts")
        .scaled(instances=WORKERS))
    return app


def _measure_steal(steal: bool) -> dict:
    """Deploy the skewed keyed pool with stealing on/off, drain the full
    burst, verify per-key order + fold-state continuity at the subscriber."""
    frames = STEAL_KEYS * STEAL_ROUNDS
    app = _steal_app()
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        sub = op.subscribe("scounts", maxsize=frames + 8)
        if steal:
            assert op.bus.enable_stealing("sevents", "scounts")
        time.sleep(0.2)  # let the worker threads boot
        t0 = time.perf_counter()
        op.start_pending_sensors()
        got = drain(sub, frames, timeout=120)
        dt = time.perf_counter() - t0
        snap = op.bus.stats()["sevents"]["groups"]["scounts"]
    violations = lost_state = 0
    per_key: dict[str, list[dict]] = {}
    for m in got:
        per_key.setdefault(m.payload["key"], []).append(m.payload["value"])
    for vals in per_key.values():
        for i, v in enumerate(vals):
            if v["seq"] != i:
                violations += 1     # out-of-order / duplicated fold
            if v["n"] != i + 1:
                lost_state += 1     # accumulator reset or forked
    return {
        "rate": len(got) / dt,
        "received": len(got),
        "violations": violations,
        "lost_state": lost_state,
        "stolen": snap.get("stolen", 0),
        "steal_denied": snap.get("steal_denied", 0),
    }


def run() -> dict:
    single, pooled = 0.0, 0.0
    drops = 0
    members = 0
    for _ in range(RUNS):
        rate, d, _ = _measure(1)
        single = max(single, rate)
        drops += d
        rate, d, members = _measure(WORKERS)
        pooled = max(pooled, rate)
        drops += d
    speedup = pooled / single

    # paired runs: which member ends up the straggler (and how many keys it
    # owns) varies with the ring draw, so the honest comparison is
    # steal-on vs steal-off within a run — gate on the best pair
    pinned, stealing, steal_speedup = 0.0, 0.0, 0.0
    stolen = steal_violations = steal_state_loss = 0
    for _ in range(STEAL_RUNS):
        r_off = _measure_steal(steal=False)
        r_on = _measure_steal(steal=True)
        ratio = r_on["rate"] / r_off["rate"] if r_off["rate"] else 0.0
        if ratio > steal_speedup:
            steal_speedup = ratio
            pinned, stealing = r_off["rate"], r_on["rate"]
        stolen += r_on["stolen"]
        for r in (r_off, r_on):
            steal_violations += r["violations"]
            steal_state_loss += r["lost_state"]
    emit("scaling_grouped_1", 1e6 / single, f"msgs_per_s={single:.0f}")
    emit(f"scaling_grouped_{WORKERS}", 1e6 / pooled,
         f"msgs_per_s={pooled:.0f}")
    emit("scaling_speedup", 0.0,
         f"{WORKERS}_workers_over_1={speedup:.2f}x")
    emit("scaling_steal", 0.0,
         f"steal_over_pinned={steal_speedup:.2f}x stolen={stolen} "
         f"ooo={steal_violations}")
    return {
        "grouped_1_msgs_per_s": round(single, 1),
        f"grouped_{WORKERS}_msgs_per_s": round(pooled, 1),
        "speedup": round(speedup, 3),
        "frames": FRAMES,
        "stages": STAGES,
        "workers": WORKERS,
        "service_time_s": SERVICE_S,
        "exit_group_members": members,
        "dropped": drops,
        "steal_pinned_msgs_per_s": round(pinned, 1),
        "steal_stealing_msgs_per_s": round(stealing, 1),
        "steal_speedup": round(steal_speedup, 3),
        "steal_skew_x": round(SKEW_SLOW_S / SKEW_FAST_S, 1),
        "stolen": stolen,
        "steal_ordering_violations": steal_violations,
        "steal_lost_state": steal_state_loss,
    }
