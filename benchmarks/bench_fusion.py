"""Claim (tentpole PR 2): device-fused stream chains beat per-hop bus routing.

The same 4-stage ``.map`` pipeline is deployed twice on a live Operator:

* **bus** — ``build(fuse=False)``: every stage is its own microservice; each
  hop is a bus subject with queue hand-off, schema validation and a thread
  wake-up per message (the v1 execution model).
* **fused** — ``build(fuse=True)``: the chain-fusion pass collapses the four
  stages into ONE unit — interior hops are in-program values.  The executor
  is backend-aware (``fusion.JIT_MODE == "auto"``): a single jitted program
  on accelerators, the host-composed chain on CPU.

When jax is importable, a third informational variant forces the jitted
program on whatever backend is present (``fused_jit``) — on CPU it documents
the XLA per-message dispatch cost that "auto" mode avoids — and a fourth
(``batched``, gated) adds the ``.scaled(max_batch=)`` knob so the backlogged
mailbox drains in bursts through ONE vmapped program call per burst: the
dispatch cost that makes per-message jit slow on CPU is amortized across the
burst, so batched throughput must beat per-message jitted throughput.

Metric: end-to-end messages/s from sensor start to the last exit message.
``run()`` returns the machine-readable variant->metric dict that
``benchmarks.run`` writes to ``BENCH_fusion.json``; CI gates on
``speedup`` (fused-default over bus) > 1.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import App, StreamSchema, connect, drain
from repro.core import fusion

from .common import emit

TENSOR = StreamSchema.device(x=((64, 64), "float32"))
# streams are lossy (drop-oldest mailboxes, capacity 256): keep the burst
# strictly under the per-instance queue size so both variants are lossless
# and the drain count is exact
FRAMES = 200
RUNS = 3  # best-of, to keep the CI gate robust to scheduler noise
MAX_BATCH = 64  # burst ceiling for the batched variant


def _app(frames: int, max_batch: int | None = None) -> App:
    app = App("fusion-bench")

    @app.driver(emits=TENSOR)
    def source(ctx, frames=FRAMES):
        base = np.ones((64, 64), np.float32)
        return ({"x": base * (i % 7)} for i in range(frames))

    exit_ = (app.sense("frames", source, frames=frames)
             .map(lambda p: {"x": p["x"] * 2.0}, emits=TENSOR, device=True,
                  name="scaled")
             .map(lambda p: {"x": p["x"] + 1.0}, emits=TENSOR, device=True,
                  name="shifted")
             .map(lambda p: {"x": p["x"].clip(0.0)}, emits=TENSOR,
                  device=True, name="rectified")
             .map(lambda p: {"x": p["x"] - 3.0}, emits=TENSOR, device=True,
                  name="normed"))
    if max_batch is not None:
        exit_.scaled(max_batch=max_batch)
    return app


def _measure(fuse: bool, frames: int = FRAMES,
             max_batch: int | None = None) -> float:
    """Deploy, push ``frames`` messages through, return messages/s."""
    app = _app(frames, max_batch)
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False, fuse=fuse)
        sub = op.subscribe("normed", maxsize=frames + 8)
        time.sleep(0.3)  # let instances boot (and the fused unit jit-warm)
        t0 = time.perf_counter()
        op.start_pending_sensors()
        got = len(drain(sub, frames, timeout=120))
        dt = time.perf_counter() - t0
    return got / dt


def run() -> dict:
    fused = max(_measure(True) for _ in range(RUNS))
    bus = max(_measure(False) for _ in range(RUNS))
    speedup = fused / bus
    emit("fusion_fused_chain", 1e6 / fused, f"msgs_per_s={fused:.0f}")
    emit("fusion_bus_chain", 1e6 / bus, f"msgs_per_s={bus:.0f}")
    emit("fusion_speedup", 0.0, f"fused_over_bus={speedup:.2f}x")
    data = {
        "fused_msgs_per_s": round(fused, 1),
        "bus_msgs_per_s": round(bus, 1),
        "speedup": round(speedup, 3),
        "frames": FRAMES,
        "stages": 4,
    }
    if fusion.jax_available():
        import jax
        import os
        # env var, not JIT_MODE: DATAX_FUSION_JIT takes precedence over the
        # module knob, so only the env var reliably forces the jitted path
        old = os.environ.get("DATAX_FUSION_JIT")
        os.environ["DATAX_FUSION_JIT"] = "always"
        try:
            # max_batch=1 pins per-message dispatch: this is the baseline
            # documenting the per-message XLA cost that batching amortizes
            fused_jit = max(_measure(True, max_batch=1) for _ in range(RUNS))
            batched = max(_measure(True, max_batch=MAX_BATCH)
                          for _ in range(RUNS))
        finally:
            if old is None:
                del os.environ["DATAX_FUSION_JIT"]
            else:
                os.environ["DATAX_FUSION_JIT"] = old
        emit("fusion_fused_jit_chain", 1e6 / fused_jit,
             f"msgs_per_s={fused_jit:.0f} backend={jax.default_backend()}")
        emit("fusion_batched_chain", 1e6 / batched,
             f"msgs_per_s={batched:.0f} max_batch={MAX_BATCH} "
             f"backend={jax.default_backend()}")
        data["fused_jit_msgs_per_s"] = round(fused_jit, 1)
        data["batched_msgs_per_s"] = round(batched, 1)
        data["max_batch"] = MAX_BATCH
        data["jit_backend"] = jax.default_backend()
    return data
