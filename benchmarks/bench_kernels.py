"""Pallas kernels vs jnp references (interpret mode on CPU).

NOTE: interpret mode executes the kernel body per grid step in Python, so
absolute numbers are NOT TPU performance — the derived column reports the
model-level quantities (FLOPs, bytes) the roofline uses instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import emit, timeit

KEY = jax.random.PRNGKey(0)


def run() -> None:
    # flash attention, GQA
    B, S, H, KH, Dh = 1, 256, 8, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KH, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KH, Dh), jnp.float32)
    flops = 4 * B * S * S * H * Dh / 2  # causal
    us = timeit(lambda: jax.block_until_ready(
        ops.flash_attention(q, k, v, causal=True)), warmup=1, iters=3)
    emit("kernel_flash_attention", us, f"flops={flops:.2e} mode=interpret")
    us = timeit(lambda: jax.block_until_ready(
        ref.flash_attention_ref(q, k, v, causal=True)), warmup=1, iters=3)
    emit("ref_flash_attention", us, f"flops={flops:.2e} backend=xla_cpu")

    # decode attention
    S = 2048
    kc = jax.random.normal(ks[1], (2, S, KH, Dh), jnp.float32)
    vc = jax.random.normal(ks[2], (2, S, KH, Dh), jnp.float32)
    qd = jax.random.normal(ks[0], (2, H, Dh), jnp.float32)
    lens = jnp.array([S, S // 2], jnp.int32)
    bytes_touched = 2 * kc.size * 4
    us = timeit(lambda: jax.block_until_ready(
        ops.decode_attention(qd, kc, vc, lens)), warmup=1, iters=3)
    emit("kernel_decode_attention", us,
         f"cache_bytes={bytes_touched:.2e} mode=interpret")

    # ssd scan
    B, L, Hh, P, N = 1, 256, 8, 32, 32
    ks5 = jax.random.split(KEY, 5)
    x = jax.random.normal(ks5[0], (B, L, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks5[1], (B, L, Hh)))
    A = -jnp.exp(jax.random.normal(ks5[2], (Hh,)) * 0.5)
    Bm = jax.random.normal(ks5[3], (B, L, 1, N))
    Cm = jax.random.normal(ks5[4], (B, L, 1, N))
    us = timeit(lambda: jax.block_until_ready(
        ops.ssd_scan(x, dt, A, Bm, Cm, chunk=64)[0]), warmup=1, iters=3)
    emit("kernel_ssd_scan", us, f"chunk=64 mode=interpret")
    us = timeit(lambda: jax.block_until_ready(
        ref.ssd_scan_ref(x, dt, A, Bm, Cm)[0]), warmup=1, iters=3)
    emit("ref_ssd_scan", us, "sequential-recurrence backend=xla_cpu")

    # rmsnorm
    x = jax.random.normal(KEY, (512, 1024), jnp.bfloat16)
    w = jnp.ones((1024,), jnp.bfloat16)
    us = timeit(lambda: jax.block_until_ready(ops.rmsnorm(x, w)),
                warmup=1, iters=3)
    emit("kernel_rmsnorm", us, f"bytes={2*x.size*2:.2e} mode=interpret")
