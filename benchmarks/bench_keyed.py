"""Claim (tentpole PR 4): keyed delivery scales STATEFUL streams.

Queue groups (PR 3) made stateless scaling add capacity, but any stateful
stage — per-key counters, per-session servers — stayed pinned to one
instance: splitting its messages round-robin would fork its state and
scramble per-key order.  Keyed delivery removes the pin: ``.key_by(field)``
hashes the field onto a stable partition ring, every message for a key goes
to the same healthy member in order, and the per-key state lives in the
stream's shared platform database (``KeyedStore``), so partitions re-home on
scale events with their state intact.

The workload is a per-key running fold (``.reduce``) with a fixed service
time per message (service-time bound, GIL-free, same rationale as
bench_scaling).  The same topology deploys twice — 1 instance vs ``WORKERS``
keyed instances — and during the pooled run one worker is force-stopped
(scale-down churn) to exercise the ordered partition hand-off.  Metric:
end-to-end messages/s, best of ``RUNS``.

Correctness is asserted, not sampled: every key's emitted fold values must be
``1..rounds`` *in order* at a single subscriber.  Any cross-member key split,
lost handoff, state reset, or ordering violation breaks the sequence —
``ordering_violations`` / ``lost_state`` are hard CI gate failures alongside
``speedup >= 2``.  Keyed delivery is pure platform code: the gate runs on
BOTH CI matrix legs (no jax required).

``run()`` returns the metric dict written to ``BENCH_keyed.json``.
"""
from __future__ import annotations

import time

from repro.core import App, FieldSpec, StreamSchema, connect, drain

from .common import emit

EVENT = StreamSchema.of(key=FieldSpec("str"), seq=FieldSpec("int"))
KEYS = 32            # distinct keys (spread over the 64-partition ring)
ROUNDS = 7           # messages per key -> 224 total, under the 256 mailbox
SERVICE_S = 0.004    # per-message service time inside the fold
WORKERS = 4
RUNS = 3             # best-of, to keep the CI gate robust to scheduler noise


def _app(instances: int):
    app = App(f"keyed-bench-{instances}")

    @app.driver(emits=EVENT)
    def source(ctx, rounds=ROUNDS):
        def gen():
            for r in range(rounds):
                for k in range(KEYS):
                    yield {"key": f"key-{k:02d}", "seq": r}
        return gen()

    def fold(acc, payload):
        time.sleep(SERVICE_S)
        n = (acc or {"n": 0})["n"]
        return {"n": n + 1, "seq": payload["seq"]}

    counts = (app.sense("events", source, rounds=ROUNDS)
              .key_by("key")
              .reduce(fold, name="counts"))
    if instances > 1:
        counts.scaled(instances=instances)
    return app


def _measure(instances: int, churn: bool) -> dict:
    """Deploy, stream every event through the keyed fold, verify per-key
    order + state continuity at the subscriber; returns rate + violations."""
    frames = KEYS * ROUNDS
    app = _app(instances)
    with connect(start=False) as op:
        app.deploy(op, start_sensors=False)
        sub = op.subscribe("counts", maxsize=frames + 8)
        time.sleep(0.2)  # let the worker threads boot
        t0 = time.perf_counter()
        op.start_pending_sensors()
        got = []
        if churn:
            # forced scale-down mid-burst: one member leaves, its partitions
            # (and their queued backlog) re-home to the survivors in order
            got.extend(drain(sub, frames // 2, timeout=120))
            victim = op.executor.instances_of("counts")[0]
            op.executor.stop_instance(victim.instance_id)
        got.extend(drain(sub, frames - len(got), timeout=120))
        dt = time.perf_counter() - t0
        stats = op.bus.stats()
        group = stats["events"]["groups"]["counts"]
        drops = sum(s["dropped"] for s in stats.values())

    ordering_violations = 0
    lost_state = 0
    per_key: dict[str, list[dict]] = {}
    for m in got:
        per_key.setdefault(m.payload["key"], []).append(m.payload["value"])
    for vals in per_key.values():
        for i, v in enumerate(vals):
            if v["seq"] != i:
                ordering_violations += 1   # out-of-order / duplicated fold
            if v["n"] != i + 1:
                lost_state += 1            # accumulator reset or forked
    return {
        "rate": len(got) / dt,
        "received": len(got),
        "ordering_violations": ordering_violations,
        "lost_state": lost_state,
        "dropped": drops,
        "rerouted": group["rerouted"],
    }


def run() -> dict:
    single, pooled = 0.0, 0.0
    violations = state_loss = drops = rerouted = 0
    for _ in range(RUNS):
        r1 = _measure(1, churn=False)
        rn = _measure(WORKERS, churn=True)
        single = max(single, r1["rate"])
        pooled = max(pooled, rn["rate"])
        for r in (r1, rn):
            violations += r["ordering_violations"]
            state_loss += r["lost_state"]
            drops += r["dropped"]
        rerouted += rn["rerouted"]
    speedup = pooled / single
    emit("keyed_stateful_1", 1e6 / single, f"msgs_per_s={single:.0f}")
    emit(f"keyed_stateful_{WORKERS}", 1e6 / pooled,
         f"msgs_per_s={pooled:.0f}")
    emit("keyed_speedup", 0.0,
         f"{WORKERS}_keyed_workers_over_1={speedup:.2f}x_with_churn")
    return {
        "keyed_1_msgs_per_s": round(single, 1),
        f"keyed_{WORKERS}_msgs_per_s": round(pooled, 1),
        "speedup": round(speedup, 3),
        "keys": KEYS,
        "rounds": ROUNDS,
        "workers": WORKERS,
        "service_time_s": SERVICE_S,
        "scale_down_during_run": True,
        "ordering_violations": violations,
        "lost_state": state_loss,
        "dropped": drops,
        "rerouted": rerouted,
    }
