"""Claim (§3/§4): serverless autoscaling driven by sidecar metrics.

Measures the reaction time from a load burst to the operator's scale-up
event, and the backlog drain speedup from the added instances.
"""
from __future__ import annotations

import time

from repro.core import (AnalyticsUnitSpec, ConfigSchema, DriverSpec,
                        FieldSpec, Operator, ScalePolicy, SensorSpec,
                        StreamSchema, StreamSpec)

from .common import emit

SCHEMA = StreamSchema.of(value=FieldSpec("int"))


def burst_driver(ctx):
    def gen():
        for i in range(int(ctx.config["n"])):
            if not ctx.running:
                return
            yield {"value": i}
    return gen()


def slow_au(ctx):
    def process(stream, payload):
        time.sleep(0.01)
        return {"value": payload["value"]}
    return process


def run() -> None:
    op = Operator(reconcile_interval_s=0.05,
                  scale_policy=ScalePolicy(backlog_high=16, backlog_low=1,
                                           idle_s=1.0, cooldown_s=0.1))
    op.register_driver(DriverSpec(name="burst", logic=burst_driver,
                                  config_schema=ConfigSchema.of(n=("int", 500)),
                                  output_schema=SCHEMA))
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="slow", logic=slow_au, output_schema=SCHEMA,
        min_instances=1, max_instances=8))
    op.start()
    op.register_sensor(SensorSpec(name="src", driver="burst",
                                  config={"n": 500}), start=False)
    op.create_stream(StreamSpec(name="out", analytics_unit="slow",
                                inputs=("src",)))
    t0 = time.monotonic()
    op.start_pending_sensors()
    scale_at = None
    max_instances = 1
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:
        n = len(op.executor.instances_of("out"))
        max_instances = max(max_instances, n)
        if scale_at is None and n > 1:
            scale_at = time.monotonic() - t0
        if op.bus.backlog("out") == 0 and n >= 1 and \
                time.monotonic() - t0 > 2:
            break
        time.sleep(0.02)
    op.shutdown()
    emit("autoscale_reaction", (scale_at or -1) * 1e6,
         f"max_instances={max_instances} policy=backlog>16")
