"""Claim (§3): effortless stream reuse — a second application subscribes to
a registered stream with no producer-side change; measures added latency."""
from __future__ import annotations

import time

from repro.core import (AnalyticsUnitSpec, ConfigSchema, DriverSpec,
                        FieldSpec, Operator, SensorSpec, StreamSchema,
                        StreamSpec)

from .common import emit

SCHEMA = StreamSchema.of(value=FieldSpec("int"), ts=FieldSpec("float"))


def run() -> None:
    op = Operator(reconcile_interval_s=0.1)

    def src(ctx):
        def gen():
            for i in range(ctx.config["n"]):
                if not ctx.running:
                    return
                time.sleep(0.002)
                yield {"value": i, "ts": time.perf_counter()}
        return gen()

    def enrich(ctx):
        return lambda s, p: {"value": p["value"] * 2, "ts": p["ts"]}

    op.register_driver(DriverSpec(name="src", logic=src,
                                  config_schema=ConfigSchema.of(n=("int", 200)),
                                  output_schema=SCHEMA))
    op.register_analytics_unit(AnalyticsUnitSpec(
        name="enrich", logic=enrich, output_schema=SCHEMA))
    op.register_sensor(SensorSpec(name="events", driver="src",
                                  config={"n": 200}), start=False)
    op.create_stream(StreamSpec(name="enriched", analytics_unit="enrich",
                                inputs=("events",)))
    # app 1 consumer + app 2 reusing the same stream
    sub1 = op.subscribe("enriched", name="app1")
    sub2 = op.subscribe("enriched", name="app2-reuser")
    op.start_pending_sensors()
    lat1, lat2 = [], []
    for _ in range(150):
        m1 = sub1.next(timeout=2.0)
        m2 = sub2.next(timeout=2.0)
        now = time.perf_counter()
        if m1:
            lat1.append((now - m1.payload["ts"]) * 1e6)
        if m2:
            lat2.append((now - m2.payload["ts"]) * 1e6)
    op.shutdown()
    lat1.sort()
    lat2.sort()
    p50_1 = lat1[len(lat1)//2] if lat1 else -1
    p50_2 = lat2[len(lat2)//2] if lat2 else -1
    emit("stream_reuse_latency", p50_2,
         f"primary_p50={p50_1:.0f}us reuse_overhead={p50_2-p50_1:.0f}us "
         f"producer_changes=0")
