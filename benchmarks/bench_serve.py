"""Serving: continuous vs static batching (tokens/s, TTFT).

Continuous batching admits requests as slots free; static batching waits for
the whole batch to finish before admitting the next wave — the difference is
the platform's serverless elasticity applied to inference.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import models
from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.serve import ServeEngine

from .common import emit


def run() -> None:
    cfg = get_smoke_config("qwen3-14b")
    run_cfg = RunConfig(attention_impl="naive", remat="none")
    params = models.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [(f"r{i}", list(rng.integers(1, cfg.vocab, 6)),
             int(rng.integers(4, 12))) for i in range(12)]

    # continuous batching
    eng = ServeEngine(cfg, run_cfg, params, n_slots=4, max_seq=64)
    t0 = time.perf_counter()
    for rid, prompt, n in reqs:
        eng.submit(rid, prompt, max_new_tokens=n)
    done = eng.run_until_idle()
    dt_cont = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    ttft = np.mean([r.first_token_at - r.arrived for r in done]) * 1e3
    emit("serve_continuous", dt_cont / toks * 1e6,
         f"tokens={toks} tok/s={toks/dt_cont:.0f} mean_ttft_ms={ttft:.0f}")

    # static batching: waves of 4, next wave only after the slowest finishes
    eng2 = ServeEngine(cfg, run_cfg, params, n_slots=4, max_seq=64)
    t0 = time.perf_counter()
    done2 = []
    for w in range(0, len(reqs), 4):
        for rid, prompt, n in reqs[w:w + 4]:
            eng2.submit(rid, prompt, max_new_tokens=n)
        done2.extend(eng2.run_until_idle())
    dt_static = time.perf_counter() - t0
    toks2 = sum(len(r.generated) for r in done2)
    emit("serve_static_waves", dt_static / toks2 * 1e6,
         f"tokens={toks2} tok/s={toks2/dt_static:.0f} "
         f"speedup_continuous={dt_static/dt_cont:.2f}x")
