"""Subprocess body for the mesh-sharded fusion benchmark.

Runs in its OWN process because the simulated device count
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``) must be set before
jax first initializes its backend — the parent benchmark process has
usually imported jax already and is pinned to one device.

The workload is a 3-stage ``tanh(x @ w)`` chain where ``w`` is a
PER-MESSAGE weight field: each burst is a batch of independent GEMMs,
which a single CPU device cannot collapse into one big multithreaded
matmul — so partitioning the batch across the mesh yields a genuine
speedup (a burst sharing one weight is just a larger GEMM and the
single device already parallelizes it internally; elementwise chains
likewise show no win).  It is also FMA-stable, so every execution path
is bit-comparable.  Three variants of the SAME fused unit are built
through the real DSL + fusion pass:

* **sharded** — the mesh path (:func:`repro.core.fusion.fusion_mesh`
  live, padded bursts divide the data axis);
* **batched** — ``DATAX_FUSION_MESH=0``: the single-device vmapped
  program, identical except for partitioning;
* **host** — ``DATAX_FUSION_JIT=never``: the host-composed chain, the
  ground truth the device paths must match bit-for-bit.

Prints one JSON dict on stdout (consumed by bench_mesh.py):
devices, per-variant msgs/s, speedup, bit_identical, and the fused
unit's ``sharded_bursts`` counter as proof the mesh path actually ran.

Usage (spawned by bench_mesh.py / tests/test_mesh.py):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python benchmarks/mesh_worker.py [--devices 4] [--rounds 40]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

# must be decided before `import jax` anywhere below
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
os.environ["DATAX_FUSION_JIT"] = "always"

import numpy as np  # noqa: E402

D = 128          # per-message x and w are both (D, D)
BURST = 64       # messages per process_batch call (pad == BURST, divisible)
WARM_ROUNDS = 2


def _build_process(app_factory):
    """DSL app -> the fused unit's live ``process`` callable."""
    from repro.core import fusion
    from repro.core.sdk import LogicContext

    application = app_factory().build()
    fused = fusion.fuse_application(application)
    unit = next(a for a in fused.analytics_units if a.fused_stages)
    ctx = LogicContext({}, db=None, instance_id="bench")
    return unit.logic(ctx)


def _app_factory():
    from repro.core import App, ShardSpec, StreamSchema
    import jax.numpy as jnp

    tensor = StreamSchema.device(
        x=((D, D), "float32", ShardSpec((None, None))),
        w=((D, D), "float32"))

    def step(p):
        # two rounds per stage: enough arithmetic per byte that the mesh
        # split dominates the (identical-in-both-variants) host stacking
        x = jnp.tanh(p["x"] @ p["w"])
        return {"x": jnp.tanh(x @ p["w"]), "w": p["w"]}

    def make():
        app = App("mesh-bench")

        @app.driver(emits=tensor)
        def frames(ctx):
            return iter(())  # driven directly via process_batch below

        (app.sense("frames", frames)
            .map(step, emits=tensor, device=True, name="proj1")
            .map(step, emits=tensor, device=True, name="proj2")
            .map(step, emits=tensor, device=True, name="proj3"))
        return app

    return make


def _bursts(rounds: int) -> list[list[dict]]:
    rng = np.random.default_rng(1)
    return [[{"x": rng.standard_normal((D, D)).astype(np.float32),
              "w": rng.standard_normal((D, D)).astype(np.float32)}
             for _ in range(BURST)] for _ in range(rounds)]


def _measure(process, bursts) -> float:
    if hasattr(process, "warmup"):
        process.warmup()
    for b in bursts[:WARM_ROUNDS]:
        process.process_batch("bench", b)
    t0 = time.perf_counter()
    for b in bursts:
        process.process_batch("bench", b)
    dt = time.perf_counter() - t0
    return (len(bursts) * BURST) / dt


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=10)
    args = ap.parse_args()

    import jax
    from repro.core import fusion

    make = _app_factory()
    bursts = _bursts(args.rounds)

    sharded = _build_process(make)
    sharded_out = sharded.process_batch("bench", bursts[0])
    sharded_rate = _measure(sharded, bursts)

    os.environ["DATAX_FUSION_MESH"] = "0"
    batched = _build_process(make)
    batched_out = batched.process_batch("bench", bursts[0])
    batched_rate = _measure(batched, bursts)

    os.environ["DATAX_FUSION_JIT"] = "never"
    host = _build_process(make)
    host_out = host.process_batch("bench", bursts[0])

    identical = all(
        np.array_equal(np.asarray(s["x"]), np.asarray(b["x"]))
        and np.array_equal(np.asarray(s["x"]), np.asarray(h["x"]))
        for s, b, h in zip(sharded_out, batched_out, host_out))

    print(json.dumps({
        "devices": jax.local_device_count(),
        "mesh_devices": sharded.stats["mesh_devices"],
        "sharded_bursts": sharded.stats["sharded_bursts"],
        "sharded_msgs_per_s": round(sharded_rate, 1),
        "batched_msgs_per_s": round(batched_rate, 1),
        "speedup": round(sharded_rate / batched_rate, 3),
        "bit_identical": bool(identical),
        "burst": BURST,
        "dim": D,
        "stages": 3,
    }))
    return 0


if __name__ == "__main__":
    sys.exit(main())
