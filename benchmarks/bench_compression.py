"""Distributed-optimization trick: gradient compression with error feedback.

Reports the wire-bytes reduction (what crosses the ICI on a real pod) and
the quantization bias with/without error feedback.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.train import optimizer as opt

from .common import emit


def run() -> None:
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1 << 16,)).astype(np.float32) * 1e-3)
    f32_bytes = g.size * 4

    for mode, wire in (("bf16", g.size * 2), ("int8_ef", g.size * 1 + 4)):
        err = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        steps = 30
        for _ in range(steps):
            deq, err = opt.compress_grad(g, err, mode)
            acc = acc + deq
        bias = float(jnp.abs(acc / steps - g).mean()) / float(
            jnp.abs(g).mean())
        emit(f"grad_compression_{mode}", 0.0,
             f"wire_reduction={f32_bytes/wire:.1f}x rel_bias={bias:.2e} "
             f"error_feedback={'yes' if mode=='int8_ef' else 'n/a'}")
