"""Claim (§1/§3): programmer productivity — "simple abstraction".

Proxy: lines of business logic needed for the fever-screening app, three ways:

* **raw bus** — hand-wired queues, threads, restart handling (inline below);
* **v1 spec-style** — tests/test_system.py's ``_fever_app`` builder
  (seven ``*Spec`` dataclasses + imperative registration);
* **v2 fluent DSL** — decorators + stream combinators (``_fever_app_v2``
  below), the same topology compiled to the same spec graph.

All three are real, runnable code; LoC excludes blanks and comments.
"""
from __future__ import annotations

import inspect
import queue
import threading

import numpy as np

from .common import emit


# --- the raw-bus implementation someone would write without the platform ---
def _raw_pipeline(n_frames: int = 5) -> int:
    qs = {name: queue.Queue() for name in
          ("rgb", "thermal", "detections", "tracks", "aligned", "fused",
           "screenings")}
    results = []
    stop = threading.Event()

    def camera(seed, out):
        rng = np.random.default_rng(seed)
        for i in range(n_frames):
            qs[out].put({"frame_id": i, "data": rng.random((8, 8))})

    def stage(inq, outq, fn):
        while not stop.is_set():
            try:
                p = qs[inq].get(timeout=0.1)
            except queue.Empty:
                continue
            r = fn(p)
            if r is not None:
                qs[outq].put(r)

    def detector(p):
        return {"frame_id": p["frame_id"], "data": p["data"] * 0.5}

    tracks_db = {}

    def tracker(p):
        tracks_db[p["frame_id"]] = True
        return p

    def alignment(p):
        return p

    pending = {}

    def fusion(p):
        o = pending.pop(p["frame_id"], None)
        if o is None:
            pending[p["frame_id"]] = p
            return None
        return {"frame_id": p["frame_id"], "data": (p["data"] + o["data"]) / 2}

    def screening(p):
        return {"frame_id": p["frame_id"], "fever": p["data"].mean() > 0.375}

    def gate():
        got = 0
        while got < n_frames and not stop.is_set():
            try:
                p = qs["screenings"].get(timeout=0.1)
            except queue.Empty:
                continue
            results.append((p["frame_id"], p["fever"]))
            got += 1

    threads = [
        threading.Thread(target=camera, args=(1, "thermal")),
        threading.Thread(target=camera, args=(2, "rgb")),
        threading.Thread(target=stage, args=("rgb", "detections", detector)),
        threading.Thread(target=stage, args=("detections", "tracks", tracker)),
        threading.Thread(target=stage, args=("thermal", "aligned", alignment)),
        threading.Thread(target=stage, args=("tracks", "fused", fusion)),
        threading.Thread(target=stage, args=("aligned", "fused", fusion)),
        threading.Thread(target=stage, args=("fused", "screenings", screening)),
        threading.Thread(target=gate),
    ]
    for t in threads:
        t.start()
    threads[-1].join(timeout=20)
    stop.set()
    for t in threads[:-1]:
        t.join(timeout=1)
    return len(results)


# --- the same topology on the v2 fluent DSL --------------------------------
def _fever_app_v2(results: list):
    from repro.core import App, FieldSpec, StreamHandle, StreamSchema

    frame = StreamSchema.of(frame_id=FieldSpec("int"),
                            data=FieldSpec("ndarray"))
    app = App("fever-screening")

    @app.driver(emits=frame)
    def camera(ctx, seed=0, frames=20):
        rng = np.random.default_rng(seed)
        return ({"frame_id": i, "data": rng.random((8, 8)).astype(np.float32)}
                for i in range(frames))

    @app.analytics_unit(expects=(frame,), emits=frame)
    def detector(ctx):
        return lambda s, p: {"frame_id": p["frame_id"], "data": p["data"] * 0.5}

    @app.analytics_unit(expects=(frame,), emits=frame, stateful=True)
    def tracker(ctx):
        table = ctx.db.ensure_table("tracks") if ctx.db else None

        def process(s, p):
            if table is not None:
                table.put(p["frame_id"], {"seen": True})
            return p
        return process

    @app.analytics_unit(expects=(frame,), emits=frame)
    def alignment(ctx):
        return lambda s, p: p

    def fuse_frames(a, b):
        return {"frame_id": a["frame_id"], "data": (a["data"] + b["data"]) / 2}

    @app.analytics_unit(expects=(frame,))
    def screening(ctx, threshold=0.25):
        return lambda s, p: {"frame_id": p["frame_id"],
                             "fever": bool(p["data"].mean() > threshold)}

    @app.actuator
    def gate(ctx):
        return lambda s, p: results.append((p["frame_id"], p["fever"]))

    app.database("tracks-db")
    thermal = app.sense("thermal", camera, seed=1, frames=20)
    rgb = app.sense("rgb", camera, seed=2, frames=20)
    tracks = (rgb.via(detector, name="detections")
                 .via(tracker, name="tracks", fixed_instances=1))
    aligned = thermal.via(alignment, name="aligned-thermal")
    fused = StreamHandle.fuse(tracks, aligned, with_=fuse_frames,
                              emits=frame, name="fused")
    fused.via(screening, name="screenings", threshold=0.375) \
         >> app.gadget("entry-gate", gate)
    return app


def _loc(obj) -> int:
    src = inspect.getsource(obj)
    return len([l for l in src.splitlines()
                if l.strip() and not l.strip().startswith("#")])


def run() -> dict:
    import sys
    sys.path.insert(0, "tests")
    from test_system import _fever_app

    assert _raw_pipeline() == 5          # the raw version must actually work
    v1_app = _fever_app([])
    v2_app = _fever_app_v2([])
    v2_app.build().validate()            # the v2 version must actually compile
    raw_loc = _loc(_raw_pipeline)
    v1_loc = _loc(_fever_app)
    v2_loc = _loc(_fever_app_v2)
    emit("loc_fever_app", 0.0,
         f"raw_loc={raw_loc} datax_v1_loc={v1_loc} datax_v2_loc={v2_loc} "
         f"v1_entities={v1_app.loc_footprint()} "
         f"v2_entities={v2_app.declared_footprint()} "
         f"note=raw version has no restart/autoscale/schema/authz")
    return {
        "raw_loc": raw_loc,
        "datax_v1_loc": v1_loc,
        "datax_v2_loc": v2_loc,
        "v1_entities": v1_app.loc_footprint(),
        "v2_entities": v2_app.declared_footprint(),
    }
