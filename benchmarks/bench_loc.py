"""Claim (§1/§3): programmer productivity — "simple abstraction".

Proxy: lines of business logic needed for the fever-screening app on DataX
(entities + logic only) vs the same topology hand-wired on the raw bus with
explicit subscriptions, threads, serialization and restart handling.  The
DataX number counts tests/test_system.py's app builder; the raw variant is
measured from the inline implementation below (it is real, runnable code).
"""
from __future__ import annotations

import inspect
import queue
import threading

import numpy as np

from .common import emit


# --- the raw-bus implementation someone would write without the platform ---
def _raw_pipeline(n_frames: int = 5) -> int:
    qs = {name: queue.Queue() for name in
          ("rgb", "thermal", "detections", "tracks", "aligned", "fused",
           "screenings")}
    results = []
    stop = threading.Event()

    def camera(seed, out):
        rng = np.random.default_rng(seed)
        for i in range(n_frames):
            qs[out].put({"frame_id": i, "data": rng.random((8, 8))})

    def stage(inq, outq, fn):
        while not stop.is_set():
            try:
                p = qs[inq].get(timeout=0.1)
            except queue.Empty:
                continue
            r = fn(p)
            if r is not None:
                qs[outq].put(r)

    def detector(p):
        return {"frame_id": p["frame_id"], "data": p["data"] * 0.5}

    tracks_db = {}

    def tracker(p):
        tracks_db[p["frame_id"]] = True
        return p

    def alignment(p):
        return p

    pending = {}

    def fusion(p):
        o = pending.pop(p["frame_id"], None)
        if o is None:
            pending[p["frame_id"]] = p
            return None
        return {"frame_id": p["frame_id"], "data": (p["data"] + o["data"]) / 2}

    def screening(p):
        return {"frame_id": p["frame_id"], "fever": p["data"].mean() > 0.375}

    def gate():
        got = 0
        while got < n_frames and not stop.is_set():
            try:
                p = qs["screenings"].get(timeout=0.1)
            except queue.Empty:
                continue
            results.append((p["frame_id"], p["fever"]))
            got += 1

    threads = [
        threading.Thread(target=camera, args=(1, "thermal")),
        threading.Thread(target=camera, args=(2, "rgb")),
        threading.Thread(target=stage, args=("rgb", "detections", detector)),
        threading.Thread(target=stage, args=("detections", "tracks", tracker)),
        threading.Thread(target=stage, args=("thermal", "aligned", alignment)),
        threading.Thread(target=stage, args=("tracks", "fused", fusion)),
        threading.Thread(target=stage, args=("aligned", "fused", fusion)),
        threading.Thread(target=stage, args=("fused", "screenings", screening)),
        threading.Thread(target=gate),
    ]
    for t in threads:
        t.start()
    threads[-1].join(timeout=20)
    stop.set()
    for t in threads[:-1]:
        t.join(timeout=1)
    return len(results)


def _loc(obj) -> int:
    src = inspect.getsource(obj)
    return len([l for l in src.splitlines()
                if l.strip() and not l.strip().startswith("#")])


def run() -> None:
    import sys
    sys.path.insert(0, "tests")
    from test_system import _fever_app

    assert _raw_pipeline() == 5          # the raw version must actually work
    datax_loc = _loc(_fever_app)
    raw_loc = _loc(_raw_pipeline)
    emit("loc_fever_app", 0.0,
         f"datax_loc={datax_loc} raw_loc={raw_loc} "
         f"note=raw version has no restart/autoscale/schema/authz")
