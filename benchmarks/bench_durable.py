"""Claim (tentpole PR 6): durable streams cost little and replay fast.

Durability is opt-in per subject: ``make_durable`` attaches an append-only
segment log and every publish appends BEFORE delivery (that ordering is what
makes replay gapless).  The design keeps the append hot path cheap — raw
encoded records, whole-segment compression at roll time — so opting in must
not halve a pipeline's throughput.  Measured here:

* ``publish_overhead_x`` — publish-loop throughput of a fire-and-forget
  subject divided by the same loop on a durable subject (in-memory log,
  default 256-record segments; the timed loop includes the segment rolls it
  triggers).  The consume side is identical for both and is drained between
  timed runs.  CI gates this at <= 2x.
* ``replay_msgs_per_s`` — catch-up rate of a late ``replay_from="earliest"``
  subscriber draining the full retained history (segment decompression +
  decode; the rate a recovering keyed member rebuilds state at).

``run()`` returns the metric dict written to ``BENCH_durable.json``.  Pure
platform code — runs on BOTH CI matrix legs (no jax required).
"""
from __future__ import annotations

import time

from repro.core import FieldSpec, MessageBus, StreamSchema
from repro.core.compression import codec_name

from .common import emit

SCHEMA = StreamSchema.of(k=FieldSpec("str"), v=FieldSpec("int"))
N = 5000             # messages per timed run
RUNS = 5             # best-of, to keep the CI gate robust to scheduler noise
BATCH = 512


def _publish_rate(bus, tok, sub) -> float:
    best = 0.0
    for _ in range(RUNS):
        t0 = time.perf_counter()
        for i in range(N):
            bus.publish("bench", {"k": f"key-{i % 16}", "v": i}, token=tok)
        best = max(best, N / (time.perf_counter() - t0))
        got = 0
        while got < N:        # drain untimed so mailboxes never overflow
            batch = sub.next_batch(BATCH, timeout=1.0)
            if not batch and sub.qsize() == 0:
                break
            got += len(batch)
    return best


def _bus(durable: bool):
    bus = MessageBus()
    bus.register_subject("bench", SCHEMA)
    if durable:
        bus.make_durable("bench")
    tok = bus.issue_token("bench", ["bench"])
    return bus, tok


def run() -> dict:
    plain_bus, plain_tok = _bus(durable=False)
    sub = plain_bus.subscribe("bench", token=plain_tok, maxsize=8192)
    plain = _publish_rate(plain_bus, plain_tok, sub)
    plain_bus.close()

    dur_bus, dur_tok = _bus(durable=True)
    sub = dur_bus.subscribe("bench", token=dur_tok, maxsize=8192)
    durable = _publish_rate(dur_bus, dur_tok, sub)
    overhead = plain / durable if durable else float("inf")
    emit("durable_publish_overhead", 0.0,
         f"plain={plain:.0f}msg/s durable={durable:.0f}msg/s "
         f"overhead={overhead:.2f}x codec={codec_name()}")

    # late-joiner catch-up: drain the whole retained history from the log
    info = dur_bus.durable_log("bench").info()
    late = dur_bus.subscribe("bench", token=dur_tok,
                             replay_from="earliest")
    depth = info["depth"]
    t0 = time.perf_counter()
    got = 0
    while got < depth:
        batch = late.next_batch(BATCH, timeout=1.0)
        if not batch and not late.replaying:
            break
        got += len(batch)
    replay = got / (time.perf_counter() - t0)
    emit("durable_replay_catchup", 0.0,
         f"replayed={got} rate={replay:.0f}msg/s "
         f"segments={info['segments']} log_bytes={info['bytes']}")
    dur_bus.close()

    return {
        "plain_msgs_per_s": round(plain, 1),
        "durable_msgs_per_s": round(durable, 1),
        "publish_overhead_x": round(overhead, 3),
        "replay_msgs_per_s": round(replay, 1),
        "replayed_records": got,
        "log_depth": depth,
        "log_segments": info["segments"],
        "log_bytes": info["bytes"],
        "codec": codec_name(),
        "messages": N,
        "runs": RUNS,
    }
