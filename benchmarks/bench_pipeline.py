"""Claim (§5): the fever-screening application (Fig. 3) runs on the platform.

End-to-end pipeline throughput: frames/s from two sensors through 5 AUs to
the gate actuator, with the platform handling all communication/scheduling.
"""
from __future__ import annotations

import sys
import time

from repro.core import Operator

from .common import emit


def run() -> None:
    sys.path.insert(0, "tests")
    from test_system import _fever_app  # the Fig. 3 analog

    results: list = []
    op = Operator(reconcile_interval_s=0.1)
    app = _fever_app(results)
    # crank the frame count up for a throughput measurement
    for s in app.sensors:
        dict(s.config)  # frozen dataclass configs are plain mappings
    app.sensors[0] = type(app.sensors[0])(
        name="thermal", driver="camera", config={"seed": 1, "frames": 300})
    app.sensors[1] = type(app.sensors[1])(
        name="rgb", driver="camera", config={"seed": 2, "frames": 300})
    t0 = time.perf_counter()
    app.deploy(op)
    op.start()
    deadline = time.monotonic() + 60
    while len(results) < 300 and time.monotonic() < deadline:
        time.sleep(0.02)
    dt = time.perf_counter() - t0
    op.shutdown()
    emit("fever_pipeline_e2e", dt / max(len(results), 1) * 1e6,
         f"frames={len(results)} fps={len(results)/dt:.0f} "
         f"entities=16 user_comm_loc=0")
