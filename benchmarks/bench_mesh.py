"""Claim (tentpole PR 8): mesh-sharded fused bursts beat single-device ones.

The batched fused program (PR 5) amortizes per-message dispatch into one
vmapped call per burst — but still runs that call on ONE device.  When a
mesh is visible (:func:`repro.core.fusion.fusion_mesh`) the burst's leading
batch axis is partitioned across it with ``NamedSharding``
(:func:`repro.kernels.ops.jit_chain_sharded`, specs derived from the stream
schema's :class:`~repro.core.schema.ShardSpec` hints), so each device runs
its slice of the same program.

The measurement happens in a SUBPROCESS (``mesh_worker.py``) with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — the CI machine has
no accelerators, so four fake host devices stand in for the mesh, exactly
as the tests do.  The worker builds the same 3-stage matmul chain through
the real DSL + fusion pass and reports sharded vs single-device-batched
``process_batch`` throughput plus bit-identity of both against the
host-composed chain.

CI gates on BENCH_mesh.json: ``speedup`` (sharded over batched) >= 1,
``bit_identical`` true, and ``sharded_bursts`` > 0 (the mesh path actually
executed, not silently fallen back).  No jax -> ``{"skipped": ...}`` and
the gate passes vacuously (minimal-deps leg).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

from .common import emit

_REPO = pathlib.Path(__file__).resolve().parent.parent
WORKER = _REPO / "benchmarks" / "mesh_worker.py"
DEVICES = 4
TIMEOUT = 600


def run() -> dict:
    try:
        import jax  # noqa: F401
    except Exception:
        emit("mesh_sharded", 0.0, "skipped=no_jax")
        return {"skipped": "jax not importable"}
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
    env["PYTHONPATH"] = str(_REPO / "src")
    env.pop("DATAX_FUSION_MESH", None)
    env.pop("DATAX_FUSION_JIT", None)
    proc = subprocess.run(
        [sys.executable, str(WORKER), "--devices", str(DEVICES)],
        env=env, cwd=str(_REPO), capture_output=True, text=True,
        timeout=TIMEOUT)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh_worker failed:\n{proc.stderr}")
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    emit("mesh_sharded_burst", 1e6 / data["sharded_msgs_per_s"],
         f"msgs_per_s={data['sharded_msgs_per_s']:.0f} "
         f"devices={data['devices']}")
    emit("mesh_batched_burst", 1e6 / data["batched_msgs_per_s"],
         f"msgs_per_s={data['batched_msgs_per_s']:.0f} devices=1")
    emit("mesh_speedup", 0.0,
         f"sharded_over_batched={data['speedup']:.2f}x "
         f"bit_identical={data['bit_identical']}")
    return data
