"""Subprocess consumer for the cross-host transport benchmark and tests.

Runs in its OWN process: connects a :class:`~repro.core.transport.RemoteBus`
to a served bus, joins a queue group (optionally keyed) and consumes with
``auto_ack=False`` — each message's ``"k,i"`` record is written + flushed to
``--outfile`` BEFORE the ack frame is sent, the same effect-then-acknowledge
discipline that makes redelivery after a crash exactly-once end-to-end: a
message is either (a) unwritten and unacked — redelivered to a survivor — or
(b) written and acked exactly once.

``--kill-after N`` simulates a consumer crash: after N acked messages the
process dies via ``os._exit`` (no unsubscribe, no socket shutdown — the
server notices via EOF/heartbeat and re-homes the member's backlog).  The
kernel flushes the TCP send buffer before FIN, so every ack sent before the
exit reaches the server — which is what makes the kill test deterministic:
the acked set and the written set are identical.

Usage (spawned by bench_transport.py / tests/test_transport.py):

    python benchmarks/transport_worker.py --addr 127.0.0.1:47000 \
        --subject ticks --group pool [--key k] --name w1 \
        --outfile /tmp/w1.log [--kill-after 200] [--batch 32]
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--addr", required=True, help="host:port of the BusServer")
    ap.add_argument("--subject", required=True)
    ap.add_argument("--group", required=True)
    ap.add_argument("--key", default=None,
                    help="payload field for keyed delivery (else plain group)")
    ap.add_argument("--name", required=True,
                    help="stable member name (the keyed ring identity)")
    ap.add_argument("--outfile", required=True,
                    help="records land here as 'k,i' lines, one per message")
    ap.add_argument("--kill-after", type=int, default=None,
                    help="os._exit after this many acked messages (crash sim)")
    ap.add_argument("--batch", type=int, default=32,
                    help="max messages pulled (and acked) per loop")
    ap.add_argument("--idle-exit", type=float, default=30.0,
                    help="clean exit after this many idle seconds")
    ap.add_argument("--no-fsync", action="store_true",
                    help="skip the per-batch fsync (pure-throughput phases "
                         "where the record file is not the recovery effect)")
    ap.add_argument("--steal", action="store_true",
                    help="join with steal=True (pull-based work stealing)")
    ap.add_argument("--slow-ms", type=float, default=0.0,
                    help="per-message service time (straggler simulation)")
    args = ap.parse_args()

    from repro.core.delivery import Group, Keyed
    from repro.core.transport import RemoteBus
    import time

    bus = RemoteBus(args.addr, peer=args.name, connect_timeout=10.0)
    token = bus.issue_token(args.name, [args.subject])
    policy = (Keyed(args.group, args.key, steal=args.steal) if args.key
              else Group(args.group, steal=args.steal))
    sub = bus.subscribe(args.subject, token=token, policy=policy,
                        name=args.name, auto_ack=False)
    consumed = 0
    last_msg = time.monotonic()
    # block-buffered: the explicit flush (+fsync) before each ack is the
    # effect-then-acknowledge barrier; line buffering would add a syscall
    # per message and throttle the coalesced-frame drain being measured
    with open(args.outfile, "a") as out:
        while True:
            msgs = sub.next_batch(args.batch, timeout=0.2)
            if not msgs:
                if sub.closed:
                    return 3  # connection dropped / subject closed
                if time.monotonic() - last_msg > args.idle_exit:
                    bus.close()
                    return 0
                continue
            last_msg = time.monotonic()
            for m in msgs:
                if args.slow_ms:
                    time.sleep(args.slow_ms / 1000.0)
                out.write(f"{m.payload['k']},{m.payload['i']}\n")
            out.flush()
            if not args.no_fsync:
                os.fsync(out.fileno())
            sub.ack(len(msgs))          # effect recorded -> acknowledge
            consumed += len(msgs)
            if args.kill_after is not None and consumed >= args.kill_after:
                os._exit(42)            # crash: no goodbye, no unsubscribe


if __name__ == "__main__":
    sys.exit(main())
