"""Claim (§3/§4): automated data communication via the platform bus.

Measures publish->receive throughput and latency, in-process and with the
full wire (msgpack+numpy) round-trip — the cost the platform absorbs so
application code contains zero communication logic.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import FieldSpec, MessageBus, StreamSchema

from .common import emit, timeit


def run() -> None:
    bus = MessageBus()
    bus.register_subject("bench", StreamSchema.of(
        x=FieldSpec("int"), arr=FieldSpec("ndarray")))
    tok = bus.issue_token("bench", ["bench"])
    payload = {"x": 1, "arr": np.zeros((64, 64), np.float32)}

    for wire in (False, True):
        sub = bus.subscribe("bench", token=tok, maxsize=4096, wire=wire)
        n = 2000

        def pump():
            for i in range(n):
                bus.publish("bench", payload, token=tok)
            got = 0
            while got < n:
                if sub.next(timeout=1.0) is not None:
                    got += 1

        us = timeit(pump, warmup=1, iters=3)
        label = "wire" if wire else "inproc"
        emit(f"bus_pubsub_{label}", us / n,
             f"throughput={n/(us/1e6):.0f}msg/s payload=16KiB")
        bus.unsubscribe(sub)

    # single-message latency
    sub = bus.subscribe("bench", token=tok, maxsize=16)
    lat = []
    for _ in range(200):
        t0 = time.perf_counter()
        bus.publish("bench", payload, token=tok)
        sub.next(timeout=1.0)
        lat.append((time.perf_counter() - t0) * 1e6)
    lat.sort()
    emit("bus_latency_p50", lat[len(lat) // 2], f"p99={lat[int(len(lat)*0.99)]:.1f}us")
