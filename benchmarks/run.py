"""Benchmark harness — one entry per paper claim/figure (DESIGN.md §9).

Prints ``name,us_per_call,derived`` CSV.  Benchmarks whose ``run()`` returns a
dict also get a machine-readable artifact ``BENCH_<name>.json`` (variant ->
metric) for CI trending and gating.  Run:

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--gate] [--out-dir D]

``--gate`` turns known regression checks into hard failures — today: the
fused device chain must beat per-hop bus execution (BENCH_fusion.json
``speedup`` > 1); batched fused execution must beat per-message jitted
dispatch on the jax leg (``batched_msgs_per_s`` >= ``fused_jit_msgs_per_s``);
4 queue-grouped workers must beat 1 by >= 2x on the
scaling pipeline (BENCH_scaling.json ``speedup``); 4 keyed *stateful*
workers must beat 1 by >= 2x with zero per-key ordering violations and zero
lost state across a forced mid-run scale-down (BENCH_keyed.json); coalesced
wire frames must be >= 2x per-message framing with exactly-once accounting
across a mid-run kill and a correctly negotiated codec on BOTH legs — zstd
with a compression win where zstandard is installed, a clean negotiate-down
to zlib where it is not (BENCH_wire.json); work stealing must recover
>= 1.5x over a pinned straggler pool with zero keyed ordering violations
(BENCH_scaling.json ``steal_*``); and
publishing on a durable subject must cost <= 2x fire-and-forget, with a
late joiner replaying the full retained history (BENCH_durable.json).  Modules
are imported lazily so a minimal-deps environment (no jax) can still run the
core benchmarks — the scaling and keyed gates are pure platform code and run
on both CI legs.
"""
from __future__ import annotations

import argparse
import importlib
import json
import pathlib
import sys
import traceback

ALL = {
    "bus": "bench_bus",
    "pipeline": "bench_pipeline",
    "autoscale": "bench_autoscale",
    "scaling": "bench_scaling",
    "keyed": "bench_keyed",
    "durable": "bench_durable",
    "transport": "bench_transport",
    "wire": "bench_wire",
    "loc": "bench_loc",
    "reuse": "bench_reuse",
    "fusion": "bench_fusion",
    "mesh": "bench_mesh",
    "kernels": "bench_kernels",
    "compression": "bench_compression",
    "serve": "bench_serve",
    "train": "bench_train",
}


def _gate(results: dict[str, dict]) -> list[str]:
    """Regression checks over the collected metric dicts."""
    failures = []
    fusion = results.get("fusion")
    if fusion is not None and fusion.get("speedup", 0.0) <= 1.0:
        failures.append(
            f"fusion: fused chain not faster than per-hop bus "
            f"(fused={fusion.get('fused_msgs_per_s')} msgs/s, "
            f"bus={fusion.get('bus_msgs_per_s')} msgs/s)")
    if fusion is not None and "batched_msgs_per_s" in fusion \
            and fusion["batched_msgs_per_s"] < fusion.get(
                "fused_jit_msgs_per_s", 0.0):
        failures.append(
            f"fusion: batched fused execution slower than per-message "
            f"jitted dispatch "
            f"(batched={fusion.get('batched_msgs_per_s')} msgs/s, "
            f"per-message={fusion.get('fused_jit_msgs_per_s')} msgs/s, "
            f"max_batch={fusion.get('max_batch')})")
    mesh = results.get("mesh")
    if mesh is not None and "skipped" not in mesh:
        if mesh.get("bit_identical") is not True:
            failures.append(
                "mesh: sharded outputs must be bit-identical to the "
                "single-device batched program and the host-composed chain")
        if mesh.get("sharded_bursts", 0) <= 0:
            failures.append(
                "mesh: the sharded path never executed (silent fallback to "
                "the single-device batched program)")
        if mesh.get("speedup", 0.0) < 1.0:
            failures.append(
                f"mesh: sharded fused bursts must not be slower than "
                f"single-device batched under "
                f"{mesh.get('devices')} devices (got "
                f"{mesh.get('speedup')}x; "
                f"sharded={mesh.get('sharded_msgs_per_s')} msgs/s, "
                f"batched={mesh.get('batched_msgs_per_s')} msgs/s)")
    scaling = results.get("scaling")
    if scaling is not None and scaling.get("speedup", 0.0) < 2.0:
        workers = scaling.get("workers", 4)
        failures.append(
            f"scaling: {workers} grouped workers must be >=2x over 1 "
            f"(got {scaling.get('speedup')}x; "
            f"pooled={scaling.get(f'grouped_{workers}_msgs_per_s')} msgs/s, "
            f"single={scaling.get('grouped_1_msgs_per_s')} msgs/s)")
    if scaling is not None and scaling.get("dropped", 0) > 0:
        failures.append(
            f"scaling: benchmark pipeline dropped "
            f"{scaling.get('dropped')} messages (should be lossless)")
    keyed = results.get("keyed")
    if keyed is not None:
        if keyed.get("speedup", 0.0) < 2.0:
            workers = keyed.get("workers", 4)
            failures.append(
                f"keyed: {workers} keyed stateful workers must be >=2x over "
                f"1 (got {keyed.get('speedup')}x; "
                f"pooled={keyed.get(f'keyed_{workers}_msgs_per_s')} msgs/s, "
                f"single={keyed.get('keyed_1_msgs_per_s')} msgs/s)")
        if keyed.get("ordering_violations", 1) != 0:
            failures.append(
                f"keyed: {keyed.get('ordering_violations')} per-key ordering "
                f"violations under scale-down churn (must be 0)")
        if keyed.get("lost_state", 1) != 0:
            failures.append(
                f"keyed: {keyed.get('lost_state')} per-key state "
                f"resets/forks across rebalance (must be 0)")
        if keyed.get("dropped", 0) > 0:
            failures.append(
                f"keyed: benchmark pipeline dropped "
                f"{keyed.get('dropped')} messages (should be lossless)")
    transport = results.get("transport")
    if transport is not None:
        if transport.get("lost", 1) != 0:
            failures.append(
                f"transport: {transport.get('lost')} messages lost across "
                f"the worker-process kill (must be 0)")
        if transport.get("duplicates", 1) != 0:
            failures.append(
                f"transport: {transport.get('duplicates')} double-deliveries "
                f"across the worker-process kill (must be 0)")
        if transport.get("ordering_violations", 1) != 0:
            failures.append(
                f"transport: {transport.get('ordering_violations')} per-key "
                f"ordering violations across the cross-process re-home "
                f"(must be 0)")
        if transport.get("delivered", -1) != transport.get("published", 0):
            failures.append(
                f"transport: delivered {transport.get('delivered')} of "
                f"{transport.get('published')} published messages")
    wire = results.get("wire")
    if wire is not None:
        if wire.get("coalesced_x", 0.0) < 2.0:
            failures.append(
                f"wire: coalesced frames must be >=2x per-message framing "
                f"(got {wire.get('coalesced_x')}x; "
                f"coalesced={wire.get('coalesced_msgs_per_s')} msgs/s, "
                f"per-message={wire.get('per_message_msgs_per_s')} msgs/s)")
        if wire.get("frames_coalesced", 0) <= 0:
            failures.append(
                "wire: the coalesced path never shipped a multi-message "
                "frame (silent fallback to per-message framing)")
        if wire.get("zstd_host"):
            # full-deps leg: the negotiated codec must be zstd and the wire
            # must actually be smaller than the raw payloads
            if wire.get("codec") != "zstd":
                failures.append(
                    f"wire: zstd available but negotiated codec is "
                    f"{wire.get('codec')!r} (must be 'zstd')")
            if not wire.get("wire_ratio") or wire["wire_ratio"] <= 1.0:
                failures.append(
                    f"wire: raw/compressed ratio must be > 1 on the zstd "
                    f"leg (got {wire.get('wire_ratio')})")
        elif not wire.get("negotiated_down"):
            # minimal-deps leg: a zlib-only host must negotiate DOWN to
            # zlib cleanly, not fail or stay un-negotiated
            failures.append(
                f"wire: zstd-less host must negotiate down to zlib "
                f"(codec={wire.get('codec')!r}, "
                f"proto={wire.get('proto')})")
        for k in ("lost", "duplicates", "ordering_violations"):
            if wire.get(k, 1) != 0:
                failures.append(
                    f"wire: {wire.get(k)} {k} across the coalesced-frame "
                    f"kill run (must be 0)")
    if scaling is not None and "steal_speedup" in scaling:
        if scaling.get("steal_speedup", 0.0) < 1.5:
            failures.append(
                f"scaling: work stealing must recover >=1.5x over the "
                f"pinned straggler pool (got {scaling.get('steal_speedup')}x; "
                f"stealing={scaling.get('steal_stealing_msgs_per_s')} msgs/s, "
                f"pinned={scaling.get('steal_pinned_msgs_per_s')} msgs/s)")
        if scaling.get("stolen", 0) <= 0:
            failures.append(
                "scaling: the steal path never moved a partition "
                "(stolen == 0 with stealing enabled)")
        if scaling.get("steal_ordering_violations", 1) != 0:
            failures.append(
                f"scaling: {scaling.get('steal_ordering_violations')} "
                f"per-key ordering violations under work stealing "
                f"(must be 0)")
        if scaling.get("steal_lost_state", 1) != 0:
            failures.append(
                f"scaling: {scaling.get('steal_lost_state')} per-key state "
                f"resets/forks under work stealing (must be 0)")
    durable = results.get("durable")
    if durable is not None:
        if durable.get("publish_overhead_x", 99.0) > 2.0:
            failures.append(
                f"durable: publishing on a durable subject must cost <= 2x "
                f"fire-and-forget (got {durable.get('publish_overhead_x')}x; "
                f"plain={durable.get('plain_msgs_per_s')} msgs/s, "
                f"durable={durable.get('durable_msgs_per_s')} msgs/s)")
        if durable.get("replayed_records", -1) != durable.get("log_depth", 0):
            failures.append(
                f"durable: late-joiner replay must drain the full retained "
                f"history (replayed {durable.get('replayed_records')} of "
                f"{durable.get('log_depth')} records)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(ALL), default=None)
    ap.add_argument("--gate", action="store_true",
                    help="fail on known benchmark regressions (CI)")
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_<name>.json artifacts are written")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    failed = 0
    results: dict[str, dict] = {}
    for name, modname in ALL.items():
        if args.only and name != args.only:
            continue
        try:
            mod = importlib.import_module(f".{modname}", package=__package__)
            data = mod.run()
            if isinstance(data, dict):
                results[name] = data
                path = out_dir / f"BENCH_{name}.json"
                path.write_text(json.dumps(data, indent=2, sort_keys=True)
                                + "\n")
                print(f"{name},0.0,artifact={path}")
        except Exception:
            failed += 1
            print(f"{name},-1,FAILED")
            traceback.print_exc()
    if args.gate:
        for failure in _gate(results):
            failed += 1
            print(f"GATE FAILED: {failure}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
