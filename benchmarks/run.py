"""Benchmark harness — one entry per paper claim/figure (DESIGN.md §9).

Prints ``name,us_per_call,derived`` CSV.  Run:
    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (bench_autoscale, bench_bus, bench_compression, bench_kernels,
               bench_loc, bench_pipeline, bench_reuse, bench_serve,
               bench_train)

ALL = {
    "bus": bench_bus,
    "pipeline": bench_pipeline,
    "autoscale": bench_autoscale,
    "loc": bench_loc,
    "reuse": bench_reuse,
    "kernels": bench_kernels,
    "compression": bench_compression,
    "serve": bench_serve,
    "train": bench_train,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(ALL), default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in ALL.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run()
        except Exception:
            failed += 1
            print(f"{name},-1,FAILED")
            traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
