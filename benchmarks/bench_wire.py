"""Claim (tentpole PR 9): coalesced frames make the wire a fast path.

PR 7's transport shipped exactly one wire frame per message; PR 9 drains the
per-peer outbound queue into a single ``msgs`` frame (up to the negotiated
``max_frame_msgs`` records) and negotiates wire compression in the ``hello``
exchange.  This benchmark runs the SAME 2-worker queue-group drain twice
against two servers — one with coalescing (``max_frame_msgs=64``), one
negotiated down to per-message framing (``max_frame_msgs=1``) — publishing
on the host bus so the wire delivery path is the only difference.  Measured:

* ``coalesced_msgs_per_s`` / ``per_message_msgs_per_s`` — drain throughput
  of each framing mode; gate: ``coalesced_x`` (their ratio) >= 2.
* ``codec`` / ``wire_ratio`` — the negotiated wire codec and the
  raw/compressed byte ratio from ``BusServer.stats()``: on the zstd leg the
  ratio is the observable compression win, on the zlib-only leg the recorded
  ``negotiated_down=True`` is the claim (a zlib peer interoperates instead
  of failing).
* ``lost`` / ``duplicates`` / ``ordering_violations`` — a keyed 2-worker
  pool with ONE member killed mid-run (``os._exit``, no goodbye) under
  coalesced framing: cumulative acks cover whole frames, so the kill must
  still re-home with 0 lost, 0 double-delivered, 0 per-key order breaks.

``run()`` returns the metric dict written to ``BENCH_wire.json``.  Pure
platform code + stdlib subprocess — runs on BOTH CI matrix legs (no jax,
no zstandard required).
"""
from __future__ import annotations

import os
import tempfile
import time

from repro.core import MessageBus
from repro.core.compression import available_codecs
from repro.core.transport import BusServer

from .bench_transport import (KEYS, SCHEMA, _publish_all, await_members,
                              ordering_violations, read_records,
                              spawn_worker, wait_for)
from .common import emit

N = 8000  # bigger burst than bench_transport: backlog is what coalesces
RUNS = 2  # best-of per framing mode, to absorb scheduler noise


def _wait_tight(published: set, outfiles: list[str],
                timeout: float = 60.0) -> list[tuple[str, int]]:
    """``bench_transport.wait_for`` with a 5ms poll: the drain under
    measurement lasts a few hundred ms, so the default 50ms poll would
    quantize the rate by double-digit percents."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        records = read_records(*outfiles)
        if set(records) >= published:
            return records
        time.sleep(0.005)
    return read_records(*outfiles)


def _publish_burst(bus, tok, subject: str) -> set:
    """N host-bus publishes, same key spread as bench_transport's
    ``_publish_all`` but sized for the coalescing measurement."""
    published = set()
    per_key = [0] * KEYS
    for n in range(N):
        j = n % KEYS
        k = f"key-{j}"
        bus.publish(subject, {"k": k, "v": n, "i": per_key[j]}, token=tok)
        published.add((k, per_key[j]))
        per_key[j] += 1
    return published


def _drain_rate(max_frame_msgs: int, tag: str) -> tuple[float, int, dict]:
    """Publish N host-bus messages into a 2-worker remote group and time the
    drain; returns (msgs/s, lost, server peer-stats snapshot)."""
    bus = MessageBus(default_queue_size=2 * N)
    bus.register_subject("wticks", SCHEMA)
    server = BusServer(bus, hb_timeout=8.0, max_frame_msgs=max_frame_msgs)
    tok = bus.issue_token("driver", ["wticks"])
    tmp = tempfile.mkdtemp(prefix=f"bench_wire_{tag}_")
    outs = [os.path.join(tmp, "w1.log"), os.path.join(tmp, "w2.log")]
    procs = [spawn_worker(server.address, "wticks", "pool", f"w{i + 1}",
                          outs[i], extra=["--no-fsync", "--batch", "64"])
             for i in range(2)]
    try:
        await_members(bus, "wticks", "pool", 2)
        t0 = time.perf_counter()
        published = _publish_burst(bus, tok, "wticks")
        records = _wait_tight(published, outs)
        dt = time.perf_counter() - t0
        lost = len(published - set(records))
        peers = server.stats()["peers"]
        snap = next(iter(peers.values())) if peers else {}
        return len(set(records)) / dt, lost, snap
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=5.0)
            except Exception:
                p.kill()
        server.close()
        bus.close()


def _kill_run() -> dict:
    """Keyed 2-worker pool under coalesced framing, one member killed
    mid-run: exactly-once accounting across whole-frame cumulative acks."""
    bus = MessageBus(default_queue_size=4096)
    bus.register_subject("kwticks", SCHEMA)
    server = BusServer(bus, hb_timeout=8.0)
    tok = bus.issue_token("driver", ["kwticks"])
    tmp = tempfile.mkdtemp(prefix="bench_wire_kill_")
    outs = [os.path.join(tmp, "k1.log"), os.path.join(tmp, "k2.log")]
    procs = [
        spawn_worker(server.address, "kwticks", "kpool", "k1", outs[0],
                     key="k", kill_after=150),
        spawn_worker(server.address, "kwticks", "kpool", "k2", outs[1],
                     key="k"),
    ]
    try:
        await_members(bus, "kwticks", "kpool", 2)
        published = _publish_all(bus, tok, "kwticks")
        records = wait_for(published, outs)
        return {
            "lost": len(published - set(records)),
            "duplicates": len(records) - len(set(records)),
            "ordering_violations": ordering_violations(outs),
        }
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=5.0)
            except Exception:
                p.kill()
        server.close()
        bus.close()


def run() -> dict:
    coalesced, per_message = 0.0, 0.0
    lost = 0
    snap: dict = {}
    for _ in range(RUNS):
        rate, lo, snap = _drain_rate(64, "coalesced")
        coalesced = max(coalesced, rate)
        lost += lo
        rate, lo, _ = _drain_rate(1, "permsg")
        per_message = max(per_message, rate)
        lost += lo
    kill = _kill_run()
    coalesced_x = coalesced / per_message if per_message else 0.0
    # the server negotiates the first common codec; with zstandard absent
    # (the minimal CI leg) BOTH sides can only offer zlib, so a recorded
    # "zlib" there is a successful negotiation-down, not a failure
    codec = snap.get("codec")
    zstd_host = "zstd" in available_codecs()
    emit("wire_coalesced", 1e6 / coalesced, f"msgs_per_s={coalesced:.0f}")
    emit("wire_per_message", 1e6 / per_message,
         f"msgs_per_s={per_message:.0f}")
    emit("wire_speedup", 0.0,
         f"coalesced_over_per_message={coalesced_x:.2f}x codec={codec} "
         f"ratio={snap.get('wire_ratio')}")
    return {
        "published": N,
        "coalesced_msgs_per_s": round(coalesced, 1),
        "per_message_msgs_per_s": round(per_message, 1),
        "coalesced_x": round(coalesced_x, 3),
        "frames_coalesced": snap.get("frames_coalesced", 0),
        "max_frame_msgs": snap.get("max_frame_msgs", 0),
        "proto": snap.get("proto", 0),
        "codec": codec,
        "wire_ratio": snap.get("wire_ratio"),
        "zstd_host": zstd_host,
        "negotiated_down": (not zstd_host) and codec == "zlib",
        "lost": lost + kill["lost"],
        "duplicates": kill["duplicates"],
        "ordering_violations": kill["ordering_violations"],
    }
