"""Training pipeline: end-to-end step time through the DataX stream graph
(corpus -> packer -> batcher -> device train step), CPU-sized model."""
from __future__ import annotations

import shutil
import time

from repro.configs import get_smoke_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer, TrainerConfig

from .common import emit


def run() -> None:
    shutil.rmtree("/tmp/repro-bench-train", ignore_errors=True)
    cfg = get_smoke_config("minitron-4b")
    rc = RunConfig(attention_impl="chunked", attention_chunk=32, remat="none")
    tcfg = TrainerConfig(global_batch=4, seq_len=64, ckpt_every=1000,
                         total_steps=100, workdir="/tmp/repro-bench-train")
    tr = Trainer(cfg, rc, tcfg)
    tr.init_or_restore()
    tr.run_steps(2)  # compile + warm the pipeline
    t0 = time.perf_counter()
    ms = tr.run_steps(8)
    dt = time.perf_counter() - t0
    tr.close()
    toks = tcfg.global_batch * tcfg.seq_len * len(ms)
    emit("train_pipeline_step", dt / max(len(ms), 1) * 1e6,
         f"steps={len(ms)} tok/s={toks/dt:.0f} "
         f"loss_first={ms[0]['loss']:.3f} loss_last={ms[-1]['loss']:.3f}")
