"""Claim (tentpole PR 7): the bus crosses processes without losing its
semantics.

A 2-process pipeline — driver publishing on the host bus in THIS process,
grouped/keyed consumers in SEPARATE worker processes attached through
:class:`~repro.core.transport.RemoteBus` — must deliver every message exactly
once, and a forced worker-process kill (``os._exit``, no goodbye) must
re-home that member's unacknowledged backlog to survivors with zero loss,
zero double-delivery, and zero per-key ordering violations.  Measured:

* ``delivered_msgs_per_s`` — wire throughput of a 2-worker queue group
  (publish on host, consume + ack over TCP, fsync per batch).
* ``lost`` / ``duplicates`` — exactly-once accounting across the kill
  (CI gates both at 0, and ``delivered == published``).
* ``ordering_violations`` — per-key order across the keyed re-home (gate: 0).

``run()`` returns the metric dict written to ``BENCH_transport.json``.  Pure
platform code + stdlib subprocess — runs on BOTH CI matrix legs (no jax).
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import tempfile
import time

from repro.core import FieldSpec, MessageBus, StreamSchema
from repro.core.transport import BusServer

from .common import emit

SCHEMA = StreamSchema.of(k=FieldSpec("str"), v=FieldSpec("int"),
                         i=FieldSpec("int"))
_REPO = pathlib.Path(__file__).resolve().parent.parent
WORKER = _REPO / "benchmarks" / "transport_worker.py"
N = 2000
KEYS = 16
WAIT_S = 60.0


def spawn_worker(addr: tuple[str, int], subject: str, group: str, name: str,
                 outfile: str, *, key: str | None = None,
                 kill_after: int | None = None,
                 extra: list[str] | None = None) -> subprocess.Popen:
    """Start one consumer process (see transport_worker.py) against a served
    bus; reused verbatim by tests/test_transport.py.  ``extra`` appends raw
    worker flags (``--no-fsync``, ``--steal``, ``--slow-ms``...)."""
    cmd = [sys.executable, str(WORKER), "--addr", f"{addr[0]}:{addr[1]}",
           "--subject", subject, "--group", group, "--name", name,
           "--outfile", outfile]
    if key:
        cmd += ["--key", key]
    if kill_after is not None:
        cmd += ["--kill-after", str(kill_after)]
    if extra:
        cmd += list(extra)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.Popen(cmd, env=env, cwd=str(_REPO))


def read_records(*outfiles: str) -> list[tuple[str, int]]:
    """Every ``(key, i)`` record the workers wrote (order preserved per
    file, files concatenated)."""
    records = []
    for path in outfiles:
        try:
            with open(path) as f:
                for line in f:
                    k, _, i = line.strip().partition(",")
                    if i:
                        records.append((k, int(i)))
        except FileNotFoundError:
            pass
    return records


def wait_for(published: set, outfiles: list[str],
             timeout: float = WAIT_S) -> list[tuple[str, int]]:
    """Poll worker outfiles until every published record appears (or
    timeout); returns the full record list (duplicates included)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        records = read_records(*outfiles)
        if set(records) >= published:
            return records
        time.sleep(0.05)
    return read_records(*outfiles)


def ordering_violations(outfiles: list[str]) -> int:
    """Per-key order regressions within each worker's own record stream.
    Keyed delivery pins a key to one member at a time and re-homes whole
    partitions in order, so each file must see every key's ``i`` strictly
    increasing."""
    bad = 0
    for path in outfiles:
        last: dict[str, int] = {}
        for k, i in read_records(path):
            if i <= last.get(k, -1):
                bad += 1
            last[k] = i
    return bad


def await_members(bus, subject: str, group: str, n: int,
                  timeout: float = WAIT_S) -> None:
    """Block until ``n`` members joined the group — the bus is
    fire-and-forget for subscriber-less subjects, so the driver must not
    start publishing before the remote members' subscriptions land (worker
    startup pays a multi-second interpreter+import cost)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        info = bus.group_info(subject, group)
        if info is not None and len(info["members"]) >= n:
            return
        time.sleep(0.05)
    raise TimeoutError(
        f"{n} remote members did not join {subject}/{group} in {timeout}s")


def _publish_all(bus, tok, subject: str) -> set:
    published = set()
    per_key = [0] * KEYS
    for n in range(N):
        j = n % KEYS
        k = f"key-{j}"
        bus.publish(subject, {"k": k, "v": n, "i": per_key[j]}, token=tok)
        published.add((k, per_key[j]))
        per_key[j] += 1
    return published


def run() -> dict:
    bus = MessageBus(default_queue_size=4096)
    bus.register_subject("ticks", SCHEMA)
    bus.register_subject("kticks", SCHEMA)
    server = BusServer(bus, hb_timeout=8.0)
    tok = bus.issue_token("driver", ["ticks", "kticks"])
    tmp = tempfile.mkdtemp(prefix="bench_transport_")
    procs: list[subprocess.Popen] = []
    try:
        # -- phase 1: 2-worker group throughput over the wire --------------
        outs = [os.path.join(tmp, "g1.log"), os.path.join(tmp, "g2.log")]
        procs += [spawn_worker(server.address, "ticks", "pool", f"g{i+1}",
                               outs[i]) for i in range(2)]
        await_members(bus, "ticks", "pool", 2)
        t0 = time.perf_counter()
        published = _publish_all(bus, tok, "ticks")
        records = wait_for(published, outs)
        wire_rate = len(set(records)) / (time.perf_counter() - t0)
        phase1_lost = len(published - set(records))
        phase1_dups = len(records) - len(set(records))

        # -- phase 2: keyed consumers, one killed mid-stream ---------------
        kouts = [os.path.join(tmp, "k1.log"), os.path.join(tmp, "k2.log")]
        procs.append(spawn_worker(server.address, "kticks", "kpool", "k1",
                                  kouts[0], key="k", kill_after=150))
        procs.append(spawn_worker(server.address, "kticks", "kpool", "k2",
                                  kouts[1], key="k"))
        await_members(bus, "kticks", "kpool", 2)
        kpublished = _publish_all(bus, tok, "kticks")
        krecords = wait_for(kpublished, kouts)
        lost = len(kpublished - set(krecords))
        duplicates = len(krecords) - len(set(krecords))
        violations = ordering_violations(kouts)

        emit("transport_wire", 0.0,
             f"2-worker wire rate={wire_rate:.0f}msg/s "
             f"kill: lost={lost} dup={duplicates} ooo={violations}")
        return {
            "published": N,
            "delivered": len(set(krecords)),
            "delivered_msgs_per_s": round(wire_rate, 1),
            "lost": lost + phase1_lost,
            "duplicates": duplicates + phase1_dups,
            "ordering_violations": violations,
            "reaped_peers": server.stats()["disconnects"],
        }
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                p.kill()
        server.close()
        bus.close()
