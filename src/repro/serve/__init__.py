"""Serving substrate: KV slot pool, continuous batcher, engine."""
from .batcher import ContinuousBatcher, Request
from .engine import ServeEngine
from .kvcache import CacheFullError, SlotAllocator

__all__ = ["ContinuousBatcher", "Request", "ServeEngine", "CacheFullError",
           "SlotAllocator"]
