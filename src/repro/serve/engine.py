"""ServeEngine — continuous-batching inference as a DataX application.

  requests (sensor) -> admission/batcher (host AU) ->
      {prefill, decode} (DEVICE AUs, pjit on the mesh) -> responses (stream)

Engine tick:
  1. plan_tick() — finish EOS/len-capped requests, free slots, admit waiters;
  2. prefill each admitted request (prompt bucketed to limit compilations),
     scatter its KV/state into the slot pool, emit its first token;
  3. one lockstep decode step over ALL live slots (per-slot positions —
     sequences at different lengths decode together);
  4. publish finished responses.

The slot table persists in a DataX database (StateStore), so an engine
restart recovers its session map — the paper's state-management claim
exercised by the serving path.
"""
from __future__ import annotations

import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig, RunConfig
from repro.core.state import Database
from repro.distributed.act_sharding import activation_mesh
from repro.models import transformer as T

from .batcher import ContinuousBatcher, Request
from .kvcache import SlotAllocator


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 2048) * 2048


class ServeEngine:
    def __init__(self, cfg: ModelConfig, run: RunConfig, params,
                 *, n_slots: int = 8, max_seq: int = 512, mesh=None,
                 db: Database | None = None, eos_id: int | None = None):
        self.cfg = cfg
        self.run = run
        self.mesh = mesh or jax.make_mesh((1, 1), ("data", "model"))
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.batcher = ContinuousBatcher(n_slots)
        self.slots = SlotAllocator(n_slots, db=db)
        self.params = params
        self.cache = models.init_cache(cfg, n_slots, max_seq)
        self.seq_lens = np.zeros((n_slots,), np.int32)
        self.last_token = np.zeros((n_slots,), np.int32)
        self.metrics = {"ticks": 0, "prefills": 0, "decode_steps": 0,
                        "tokens_generated": 0}
        self._decode = self._build_decode()
        self._prefill_cache: dict[int, Any] = {}

    # ------------------------------------------------------------------ jits
    def _build_decode(self):
        cfg, run, mesh = self.cfg, self.run, self.mesh

        def step(params, cache, batch):
            with activation_mesh(mesh):
                logits, cache = models.decode_step(params, cache, batch,
                                                   cfg, run)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        return jax.jit(step, donate_argnums=(1,))

    def _get_prefill(self, plen: int):
        if plen not in self._prefill_cache:
            cfg, run, mesh, max_seq = self.cfg, self.run, self.mesh, self.max_seq

            def prefill(params, batch):
                with activation_mesh(mesh):
                    return T.prefill_with_cache(params, batch, cfg, run,
                                                max_seq)

            self._prefill_cache[plen] = jax.jit(prefill)
        return self._prefill_cache[plen]

    @functools.cached_property
    def _insert_fns(self):
        """Per-leaf jitted slot inserts (donated pool)."""
        def insert_kv(pool, piece, slot, plen):
            # pool [L, B, S, ...]; piece [L, 1, Sp, ...]
            return jax.lax.dynamic_update_slice(
                pool, piece.astype(pool.dtype),
                (0, slot, 0) + (0,) * (pool.ndim - 3))

        def insert_state(pool, piece, slot, plen):
            # pool [L, B, ...]; piece [L, 1, ...]
            return jax.lax.dynamic_update_slice(
                pool, piece.astype(pool.dtype),
                (0, slot) + (0,) * (pool.ndim - 2))

        return (jax.jit(insert_kv, donate_argnums=(0,)),
                jax.jit(insert_state, donate_argnums=(0,)))

    # -------------------------------------------------------------- lifecycle
    def submit(self, request_id, prompt: list[int],
               max_new_tokens: int = 32) -> None:
        self.batcher.submit(Request(request_id=request_id, prompt=list(prompt),
                                    max_new_tokens=max_new_tokens,
                                    eos_id=self.eos_id))

    def _do_prefill(self, req: Request) -> None:
        plen = len(req.prompt)
        if self.cfg.family in ("ssm", "hybrid", "moe"):
            # ssm/hybrid: recurrent state is taken at the end of the prompt —
            # padding would roll garbage into it.  moe: pad tokens compete
            # for expert capacity in the router (26 identical pad
            # first-choices can fill an expert ahead of a real token's
            # second choice, changing real logits) -> exact-length prefill.
            # TODO(production): thread a routing validity mask instead.
            bucket = plen
        else:
            # causal attention ignores right-padding (masked by seq_lens)
            bucket = min(_bucket(plen), self.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        batch = {"tokens": jnp.asarray(toks),
                 "last_index": jnp.asarray([plen - 1], jnp.int32)}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_seq, self.cfg.d_model),
                jnp.dtype(self.cfg.activation_dtype))
        logits, small = self._get_prefill(bucket)(self.params, batch)
        # NOTE: right-padded prompts attend causally, so positions < plen are
        # unaffected by the padding; states for SSM families are taken at the
        # bucket end — we therefore bucket SSM prompts exactly.
        slot = self.slots.alloc(req.request_id)
        req.slot = slot
        insert_kv, insert_state = self._insert_fns
        for name, pool in self.cache.items():
            piece = small[name]
            if name in ("k", "v", "xk", "xv"):
                self.cache[name] = insert_kv(pool, piece, slot, plen)
            else:
                self.cache[name] = insert_state(pool, piece, slot, plen)
        first = int(np.asarray(logits)[0].argmax())
        req.generated.append(first)
        req.prefill_done = True
        req.first_token_at = time.monotonic()
        self.seq_lens[slot] = plen
        self.last_token[slot] = first
        self.metrics["prefills"] += 1

    def _do_decode(self, live: list[Request]) -> None:
        active = np.zeros((self.n_slots,), bool)
        for req in live:
            active[req.slot] = True
        batch = {
            "tokens": jnp.asarray(self.last_token[:, None]),
            "seq_lens": jnp.asarray(self.seq_lens),
            "active": jnp.asarray(active),
        }
        next_tok, self.cache = self._decode(self.params, self.cache, batch)
        next_tok = np.asarray(next_tok)
        for req in live:
            s = req.slot
            self.seq_lens[s] += 1
            tok = int(next_tok[s])
            req.generated.append(tok)
            self.last_token[s] = tok
            self.metrics["tokens_generated"] += 1
        self.metrics["decode_steps"] += 1

    def tick(self) -> list[Request]:
        """One engine iteration; returns requests finished this tick."""
        plan = self.batcher.plan_tick(self.slots.n_free)
        for req in plan.finished:
            self.slots.free(req.request_id)
        for req in plan.admit:
            self._do_prefill(req)
        if plan.decode:
            self._do_decode(plan.decode)
        self.metrics["ticks"] += 1
        return plan.finished

    def run_until_idle(self, max_ticks: int = 10_000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_ticks):
            done.extend(self.tick())
            if self.batcher.idle:
                break
        done.extend(self.tick())  # flush final finishes
        return done
