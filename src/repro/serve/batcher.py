"""Continuous batching scheduler.

Decides, each engine tick, which requests to prefill (admit) and which
slots to decode.  Policy: admit waiting requests whenever slots are free
(prefill-priority, bounded by max_prefill_batch), then decode every live
slot in one lockstep step.  Requests finish on EOS or max_new_tokens and
release their slot immediately — the next waiting request takes it on the
following tick (continuous batching).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any


@dataclasses.dataclass
class Request:
    request_id: Any
    prompt: list                 # token ids
    max_new_tokens: int = 32
    eos_id: int | None = None
    arrived: float = dataclasses.field(default_factory=time.monotonic)
    # filled by the engine:
    slot: int | None = None
    generated: list = dataclasses.field(default_factory=list)
    prefill_done: bool = False
    first_token_at: float | None = None
    finished_at: float | None = None

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated \
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens


@dataclasses.dataclass
class Tick:
    admit: list      # requests to prefill this tick
    decode: list     # live requests to decode this tick
    finished: list   # requests that completed last tick (slots released)


class ContinuousBatcher:
    def __init__(self, n_slots: int, max_prefill_per_tick: int = 1):
        self.n_slots = n_slots
        self.max_prefill_per_tick = max_prefill_per_tick
        self.waiting: deque[Request] = deque()
        self.live: dict[Any, Request] = {}
        self.completed: list[Request] = []

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def plan_tick(self, free_slots: int) -> Tick:
        finished = [r for r in self.live.values() if r.done]
        for r in finished:
            r.finished_at = time.monotonic()
            del self.live[r.request_id]
            self.completed.append(r)
        free = free_slots + len(finished)
        admit = []
        while self.waiting and free > 0 and \
                len(admit) < self.max_prefill_per_tick:
            req = self.waiting.popleft()
            admit.append(req)
            free -= 1
        for r in admit:
            self.live[r.request_id] = r
        decode = [r for r in self.live.values() if r.prefill_done]
        return Tick(admit=admit, decode=decode, finished=finished)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.live
