"""KV-cache slot management for continuous batching.

The device cache is a fixed pool of B slots (allocated once, shapes from
models.init_cache); the host-side :class:`SlotAllocator` maps live requests
to slots.  Sequences join/leave the batch independently (per-slot write
positions in the decode step), so a finished request's slot is immediately
reusable — vLLM-style continuous batching at slot granularity.  The slot
table lives in a DataX StateStore database (the paper's platform-managed
state): engine restarts recover the serving session map from it.
"""
from __future__ import annotations

import threading
from typing import Any

from repro.core.state import Database


class CacheFullError(RuntimeError):
    pass


class SlotAllocator:
    """Thread-safe map request_id -> cache slot."""

    def __init__(self, n_slots: int, db: Database | None = None):
        self.n_slots = n_slots
        self._free = list(range(n_slots - 1, -1, -1))
        self._owner: dict[int, Any] = {}
        self._by_request: dict[Any, int] = {}
        self._lock = threading.Lock()
        self._table = db.ensure_table("kv_slots",
                                      ["request_id", "len"]) if db else None
        if self._table is not None:  # recover session map on restart
            for slot, row in self._table.scan():
                if slot in self._free:
                    self._free.remove(slot)
                self._owner[slot] = row["request_id"]
                self._by_request[row["request_id"]] = slot

    def alloc(self, request_id) -> int:
        with self._lock:
            if not self._free:
                raise CacheFullError(f"all {self.n_slots} KV slots in use")
            slot = self._free.pop()
            self._owner[slot] = request_id
            self._by_request[request_id] = slot
            if self._table is not None:
                self._table.put(slot, {"request_id": request_id, "len": 0})
            return slot

    def free(self, request_id) -> int:
        with self._lock:
            slot = self._by_request.pop(request_id)
            del self._owner[slot]
            self._free.append(slot)
            if self._table is not None:
                self._table.delete(slot)
            return slot

    def slot_of(self, request_id) -> int | None:
        with self._lock:
            return self._by_request.get(request_id)

    def live_slots(self) -> dict:
        with self._lock:
            return dict(self._owner)

    @property
    def n_free(self) -> int:
        with self._lock:
            return len(self._free)
