"""Fused RMSNorm — Pallas TPU kernel.

Fuses the f32 upcast, mean-of-squares, rsqrt and scale into one VMEM pass
(the unfused jnp version round-trips x to HBM three times).  Row-blocked:
grid (rows/br,), each step normalizes a [br, D] tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: [..., D]; w: [D]."""
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, D)
    br = min(block_rows, rows)
    nr = pl.cdiv(rows, br)
    if rows % br:
        x2 = jnp.pad(x2, ((0, nr * br - rows), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * br, D), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
