"""Mamba2 SSD chunked scan — Pallas TPU kernel (arXiv:2405.21060 §6).

TPU adaptation of the SSD algorithm (the CUDA version leans on warp-level
matmul fragments; here the unit of work is a VMEM-resident chunk):

* grid (B, H/bh, L/Q) — the innermost dimension walks chunks IN ORDER; the
  running inter-chunk state S [bh, N, P] lives in VMEM scratch, making the
  sequential-grid recurrence the inter-chunk scan (no cross-core sync);
* per step, the quadratic intra-chunk term runs on the MXU:
  (C·Bᵀ ⊙ decay) @ (dt·x), with Q×Q attention-like scores per head-block;
* B/C are per-group (GVA); the group tile is broadcast across the head
  block, so head-blocks never re-read B/C from HBM.

Layouts: x [B, L, H, P]; dt [B, L, H]; A [H]; Bm/Cm [B, L, G, N] with G=1
(the assigned configs all use a single B/C group).
Returns (y [B, L, H, P], final_state [B, H, N, P]).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fs_ref, s_ref, *,
            chunk: int, seq_len: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, :, :, :].astype(jnp.float32)        # [Q, bh, P]
    dt = dt_ref[0, :, :].astype(jnp.float32)         # [Q, bh]
    A = a_ref[:].astype(jnp.float32)                 # [bh]
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # [Q, N]

    # zero padded tail positions (seq_len may not divide by chunk)
    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, dt.shape, 0)
    dt = jnp.where(pos < seq_len, dt, 0.0)           # a=exp(0)=1, xdt=0

    dA = dt * A[None, :]                             # [Q, bh] (negative)
    cum = jnp.cumsum(dA, axis=0)
    seg = cum[-1, :]                                 # [bh]
    xdt = x * dt[:, :, None]                         # [Q, bh, P]

    # ---- intra-chunk: per head-block MXU matmuls --------------------------
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    li = cum[:, None, :]                             # [Q, 1, bh]
    lj = cum[None, :, :]                             # [1, Q, bh]
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = (iq >= jq)[:, :, None]
    # min-clamp is exact for valid (i>=j) entries and prevents exp overflow
    # on masked ones (see models/mamba2.py)
    M = jnp.where(tril, cb[:, :, None] * jnp.exp(jnp.minimum(li - lj, 0.0)),
                  0.0)                               # [Q, Q, bh]
    # y_intra[i,h,p] = Σ_j M[i,j,h]·xdt[j,h,p]  — batched over h on the MXU
    y_intra = jax.lax.dot_general(
        M.transpose(2, 0, 1), xdt.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)          # [bh, Q, P]

    # ---- inter-chunk: contribution of the carried state --------------------
    S_prev = s_ref[...]                              # [bh, N, P]
    # y_inter[i,h,p] = Σ_n C[i,n]·S_prev[h,n,p]·exp(cum[i,h])
    y_inter = jax.lax.dot_general(
        Cm, S_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [Q, bh, P]
    y_inter = y_inter * jnp.exp(cum)[:, :, None]
    y = y_intra.transpose(1, 0, 2) + y_inter
    y_ref[0, :, :, :] = y.astype(y_ref.dtype)

    # ---- state update -------------------------------------------------------
    # S_c[h,n,p] = Σ_j B[j,n]·xdt[j,h,p]·exp(seg[h]-cum[j,h])
    w = jnp.exp(seg[None, :] - cum)                  # [Q, bh]
    xw = xdt * w[:, :, None]                         # [Q, bh, P]
    S_c = jax.lax.dot_general(
        Bm, xw, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # [N, bh, P]
    s_ref[...] = (S_prev * jnp.exp(seg)[:, None, None]
                  + S_c.transpose(1, 0, 2))

    @pl.when(ci == nc - 1)
    def _emit_state():
        fs_ref[0, :, :, :] = s_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_h", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 128, block_h: int = 8,
             interpret: bool = False):
    """x [B,L,H,P]; dt [B,L,H]; A [H]; Bm/Cm [B,L,1,N] -> (y, final_state)."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    assert Bm.shape[2] == 1, "kernel assumes a single B/C group (G=1)"
    chunk = min(chunk, L)
    block_h = min(block_h, H)
    nc = pl.cdiv(L, chunk)
    nh = pl.cdiv(H, block_h)
    Lp = nc * chunk
    if Lp != L:
        pad = Lp - L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    kernel = functools.partial(_kernel, chunk=chunk, seq_len=L)
    y, fs = pl.pallas_call(
        kernel,
        grid=(B, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_h, P),
                         lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, block_h), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((block_h,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_h, P),
                         lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, block_h, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Lp, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_h, N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y[:, :L], fs
