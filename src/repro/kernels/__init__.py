"""Pallas TPU kernels for the compute hot-spots, with jnp oracles.

kernels: flash_attention (prefill), decode_attention (flash-decoding),
ssd_scan (Mamba2 SSD), rmsnorm (fused norm).  See ops.py for the public
wrappers and ref.py for the allclose oracles.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
