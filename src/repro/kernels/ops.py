"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python per grid step, bit-accurate to the TPU lowering's
semantics.  On TPU they compile to Mosaic.  `interpret=None` auto-detects.
"""
from __future__ import annotations

import jax

from . import decode_attention as _da
from . import flash_attention as _fa
from . import rmsnorm as _rn
from . import ssd_scan as _ss


def _auto(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_auto(interpret))


def decode_attention(q, k_cache, v_cache, lens, *, block_s: int = 512,
                     interpret: bool | None = None):
    return _da.decode_attention(q, k_cache, v_cache, lens, block_s=block_s,
                                interpret=_auto(interpret))


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, block_h: int = 8,
             interpret: bool | None = None):
    return _ss.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, block_h=block_h,
                        interpret=_auto(interpret))


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    return _rn.rmsnorm(x, w, eps=eps, block_rows=block_rows,
                       interpret=_auto(interpret))


def jit_chain(stages):
    """Compose stream-combinator stages into ONE jitted program.

    ``stages`` is a sequence of ``(kind, fn)`` where ``kind`` is ``"map"``
    (``fn(payload) -> payload``) or ``"filter"`` (``fn(payload) -> bool``).
    Returns a jitted ``program(payload) -> (payload, keep)``: interior hops
    become in-program values (no bus traffic, no per-hop dispatch), and filter
    predicates are *predicated* — every stage runs, the combined keep flag
    decides on the host whether the exit message is emitted.  This is the
    device executor behind the chain-fusion pass (core/fusion.py).
    """
    import jax.numpy as jnp

    def program(payload):
        keep = jnp.asarray(True)
        for kind, fn in stages:
            if kind == "filter":
                keep = jnp.logical_and(keep, jnp.asarray(fn(payload)))
            else:
                payload = fn(payload)
        return payload, keep

    return jax.jit(program)


def jit_chain_batched(stages):
    """Batched variant of :func:`jit_chain`: ONE vmapped + jitted program over
    a whole burst of payloads.

    Input is the per-message payload dict with every field stacked along a
    leading batch dimension; output is ``(stacked_payload, keep_mask)`` where
    ``keep_mask`` is a ``(N,)`` bool of per-message predicated-filter
    decisions.  Per-message semantics are exactly ``jit_chain``'s — the vmap
    axis only amortizes the per-message XLA dispatch + host<->device sync
    that dominates short chains under load (the fused_jit vs host gap in
    BENCH_fusion.json) into one device call per burst.
    """
    import jax.numpy as jnp

    def single(payload):
        keep = jnp.asarray(True)
        for kind, fn in stages:
            if kind == "filter":
                keep = jnp.logical_and(keep, jnp.asarray(fn(payload)))
            else:
                payload = fn(payload)
        return payload, keep

    return jax.jit(jax.vmap(single))


def jit_chain_sharded(stages, mesh, specs=None):
    """Mesh-partitioned variant of :func:`jit_chain_batched`.

    Same contract — ``program(stacked_payload) -> (stacked_payload,
    keep_mask)`` with a leading burst dimension — but every input field is
    first committed to a ``NamedSharding`` over ``mesh``: the leading burst
    dim splits across the mesh's first axis by default, and ``specs`` (a
    field-name -> PartitionSpec mapping, as produced by
    :func:`repro.distributed.sharding.burst_spec` from the stream schema's
    sharding hints) overrides per field.  jit then compiles ONE SPMD
    program per batch shape — each device traces its slice of the burst,
    XLA propagates output shardings — so the same vmapped chain that
    amortizes dispatch on one device scales across all visible devices.
    Per-row results are bit-identical to :func:`jit_chain_batched` (vmap
    rows are independent; partitioning only changes which device computes
    a row).  The caller guarantees the leading dim divides the mesh's data
    axis — indivisible bursts must stay on the single-device program.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    batched = jit_chain_batched(stages)
    specs = dict(specs or {})
    default = PartitionSpec(mesh.axis_names[0])

    def program(payload):
        placed = {
            k: jax.device_put(v, NamedSharding(mesh, specs.get(k, default)))
            for k, v in payload.items()}
        return batched(placed)

    return program
