"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Deliberately simple O(S²)/sequential implementations — readable, obviously
correct, and independent of the kernels' blocking strategy.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: [B,Sq,H,Dh]; k/v: [B,Sk,KH,Dh] (GQA: H = KH·G)."""
    B, Sq, H, Dh = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k.astype(jnp.float32))
    s = s / math.sqrt(Dh)
    if causal:
        mask = jnp.arange(Sk)[None, :] > jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, :, None, None, :], -2.0e30, s)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dh).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lens):
    """q: [B,H,Dh]; caches [B,S,KH,Dh]; lens [B]."""
    B, H, Dh = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    qg = q.reshape(B, KH, G, Dh).astype(jnp.float32) / math.sqrt(Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, -2.0e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, Dh).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Sequential state-space recurrence (the SSD ground truth).

    x [B,L,H,P]; dt [B,L,H]; A [H]; Bm/Cm [B,L,G,N].
    Returns (y [B,L,H,P], final_state [B,H,N,P]).
    """
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    HperG = H // G
    f32 = jnp.float32
    Bh = jnp.repeat(Bm.astype(f32), HperG, axis=2)   # [B,L,H,N]
    Ch = jnp.repeat(Cm.astype(f32), HperG, axis=2)

    def step(S, inputs):
        x_t, dt_t, B_t, C_t = inputs                 # [B,H,P],[B,H],[B,H,N]x2
        a = jnp.exp(dt_t * A.astype(f32))            # [B,H]
        S = S * a[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhnp", B_t, x_t.astype(f32) * dt_t[..., None])
        y = jnp.einsum("bhn,bhnp->bhp", C_t, S)
        return S, y

    S0 = jnp.zeros((Bsz, H, N, P), f32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt.astype(f32), 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    S_final, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), S_final


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(x.dtype)
