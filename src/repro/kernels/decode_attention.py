"""Flash-decoding — single-token attention over a long KV cache (Pallas TPU).

One new token per sequence attends to a cache of S past positions.  The
arithmetic intensity is O(1) FLOP/byte (every cache byte is read once), so
the kernel is engineered for HBM streaming, not MXU:

* grid (B, KH, S/bs) — innermost dim walks the cache sequentially while
  (acc, m, l) for all G q-heads of this kv-head ride in VMEM scratch
  (split-K flash-decoding, recurrence via sequential grid);
* the per-sequence valid length arrives via scalar prefetch (SMEM) and
  masks the tail block — no host-side padding logic;
* q is pre-reshaped [B, KH, G, Dh] so one grid step consumes a [G, Dh]
  q-tile and a [bs, Dh] cache tile, emitting [G, bs] scores on the MXU.

Layouts: q [B, KH, G, Dh]; k/v cache [B, S, KH, Dh]; lens [B] i32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e30


def _kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_s: int):
    b = pl.program_id(0)
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = lens_ref[b]
    s_start = si * block_s

    @pl.when(s_start < length)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale      # [G, Dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # [bs, Dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [G,bs]
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos >= length, NEG_INF, s)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lens: jax.Array, *, block_s: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: [B, H, Dh]; caches [B, S, KH, Dh]; lens [B] -> out [B, H, Dh]."""
    B, H, Dh = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(Dh)
    block_s = min(block_s, S)
    ns = pl.cdiv(S, block_s)
    if S % block_s:
        pad = ns * block_s - S
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(B, KH, G, Dh)
    lens = lens.astype(jnp.int32)

    kernel = functools.partial(_kernel, scale=scale, block_s=block_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KH, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, kh, si, lens: (b, kh, 0, 0)),
            pl.BlockSpec((1, block_s, 1, Dh),
                         lambda b, kh, si, lens: (b, si, kh, 0)),
            pl.BlockSpec((1, block_s, 1, Dh),
                         lambda b, kh, si, lens: (b, si, kh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, kh, si, lens: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, Dh), q.dtype),
        interpret=interpret,
    )(lens, qg, k_cache, v_cache)
    return out.reshape(B, H, Dh)
