"""Flash attention (prefill) — Pallas TPU kernel.

Blocked online-softmax attention with GQA, causal masking and block-level
causal skipping.  TPU-native design decisions (vs. a CUDA port):

* the grid's innermost (sequential) dimension walks KV blocks, carrying the
  running (acc, m, l) in VMEM scratch — TPU grid steps execute in order on
  one core, so the scratch IS the inter-block recurrence, no atomics;
* BlockSpecs tile HBM->VMEM so each step touches (block_q × head_dim) of Q
  and (block_k × head_dim) of K/V — MXU-aligned (multiples of 128 for f32
  lanes / 8 sublanes; head_dim up to 128 fits one register tile);
* fully-masked causal blocks are skipped with @pl.when (no MXU work), which
  halves the FLOPs of the naive full-matrix schedule.

Layout: q [B, Sq, H, Dh]; k/v [B, Sk, KH, Dh]; H = KH·G.
Grid: (B, H, Sq/bq, Sk/bk); K/V index_map sends q-head h to kv-head h//G.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, scale: float, block_q: int, block_k: int,
            seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # block-level causal skip: block is live unless every kv pos > every q pos
    live = jnp.logical_or(not causal, k_start <= q_start + block_q - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # [bq, Dh]
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [bk, Dh]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                              block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                              block_k), 1)
        invalid = kpos >= seq_k                                # kv padding
        if causal:
            invalid = jnp.logical_or(invalid, kpos > qpos)
        s = jnp.where(invalid, NEG_INF, s)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jax.Array:
    """q: [B, Sq, H, Dh]; k/v: [B, Sk, KH, Dh] -> [B, Sq, H, Dh]."""
    B, Sq, H, Dh = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(Dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)
    if Sq % block_q:
        q = jnp.pad(q, ((0, 0), (0, nq * block_q - Sq), (0, 0), (0, 0)))
    if Sk % block_k:
        k = jnp.pad(k, ((0, 0), (0, nk * block_k - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, nk * block_k - Sk), (0, 0), (0, 0)))

    kernel = functools.partial(_kernel, causal=causal, scale=scale,
                               block_q=block_q, block_k=block_k, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, Dh),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, Dh),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, Dh),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, Dh),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq * block_q, H, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dh), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
