"""Data pipeline: corpus driver + packing/batching AUs + modality stubs."""
from . import corpus, pipeline

__all__ = ["corpus", "pipeline"]
