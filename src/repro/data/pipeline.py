"""Host-side data pipeline AUs: sequence packing and batching.

These are DataX analytics units (the paper's transformation microservices):

  corpus (sensor) --docs--> packer (AU) --sequences--> batcher (AU) --batches-->
      device-feed / train-step (device AU)

The packer concatenates documents into fixed-length training sequences
(standard LM sequence packing; no padding waste).  The batcher accumulates
``global_batch`` sequences into one numpy batch message.  Both are pure
business logic against the 3-method SDK — zero communication code, which is
the paper's productivity claim made concrete.
"""
from __future__ import annotations

import numpy as np

from repro.core.schema import ConfigSchema, FieldSpec, StreamSchema

PACKER_CONFIG = ConfigSchema.of(seq_len=("int", 1024))
PACKED_SCHEMA = StreamSchema.of(
    tokens=FieldSpec("ndarray", shape=(-1,), dtype="int32"))

BATCHER_CONFIG = ConfigSchema.of(batch=("int", 8))
BATCH_SCHEMA = StreamSchema.of(
    tokens=FieldSpec("ndarray", shape=(-1, -1), dtype="int32"),
    labels=FieldSpec("ndarray", shape=(-1, -1), dtype="int32"),
)


def packer_au(ctx):
    """Concatenate docs into (seq_len+1)-token sequences (+1 for the label
    shift); carries leftover tokens across documents."""
    seq_len = ctx.config["seq_len"] + 1
    buf: list[np.ndarray] = []
    buffered = 0

    def process(stream: str, payload: dict):
        nonlocal buffered
        buf.append(np.asarray(payload["tokens"], dtype=np.int32))
        buffered += len(buf[-1])
        out = []
        if buffered >= seq_len:
            cat = np.concatenate(buf)
            n = len(cat) // seq_len
            for i in range(n):
                out.append({"tokens": cat[i * seq_len:(i + 1) * seq_len]})
            rest = cat[n * seq_len:]
            buf.clear()
            buf.append(rest)
            buffered = len(rest)
        return out

    return process


def batcher_au(ctx):
    """Collect `batch` sequences -> {'tokens': [B,S], 'labels': [B,S]}."""
    batch = ctx.config["batch"]
    acc: list[np.ndarray] = []

    def process(stream: str, payload: dict):
        acc.append(np.asarray(payload["tokens"], dtype=np.int32))
        if len(acc) < batch:
            return None
        seqs = np.stack(acc)
        acc.clear()
        return {"tokens": seqs[:, :-1].copy(), "labels": seqs[:, 1:].copy()}

    return process
