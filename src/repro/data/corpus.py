"""Synthetic LM corpus — the 'sensor' of the training application.

A driver (DataX entity) that emits documents: variable-length token
sequences with Zipfian token statistics (deterministic per seed+doc-id, so
restarts resume identically).  Real deployments swap this driver for a file
or object-store reader; the downstream stream graph is unchanged — that is
the paper's stream-reuse claim doing real work.
"""
from __future__ import annotations

import numpy as np

from repro.core.schema import ConfigSchema, FieldSpec, StreamSchema

CORPUS_CONFIG = ConfigSchema.of(
    vocab=("int", 32000),
    seed=("int", 0),
    mean_doc_len=("int", 512),
    n_docs=("int", 1_000_000),
    start_doc=("int", 0),
)

CORPUS_SCHEMA = StreamSchema.of(
    doc_id=FieldSpec("int"),
    tokens=FieldSpec("ndarray", shape=(-1,), dtype="int32"),
)


def synth_doc(doc_id: int, vocab: int, mean_len: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003)
                                + np.uint64(doc_id))
    length = int(np.clip(rng.geometric(1.0 / mean_len), 8, 4 * mean_len))
    # zipf-ish unigram over the vocab, cheap approximation
    u = rng.random(length)
    toks = np.minimum((vocab - 2) * u ** 3, vocab - 2).astype(np.int32) + 1
    toks[0] = 0  # BOS
    return toks


def corpus_driver(ctx):
    """Callback-style driver factory: yields {'doc_id', 'tokens'}."""
    cfg = ctx.config

    def gen():
        for doc_id in range(cfg["start_doc"], cfg["n_docs"]):
            if not ctx.running:
                return
            yield {"doc_id": doc_id,
                   "tokens": synth_doc(doc_id, cfg["vocab"],
                                       cfg["mean_doc_len"], cfg["seed"])}

    return gen()
