"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
  memory     = HLO_bytes_per_device / HBM_BW
  collective = Σ wire_bytes_per_device(op) / (ICI_BW_PER_LINK · links)

``compiled.cost_analysis()`` supplies per-device FLOPs/bytes (the module XLA
compiles is the per-partition SPMD program).  Collective bytes are NOT in
cost_analysis, so we parse the optimized HLO text and apply per-op ring-cost
factors:

  all-reduce          2·(n-1)/n · tensor_bytes     (ring AR)
  all-gather          (n-1)/n   · output_bytes
  reduce-scatter      (n-1)/n   · input_bytes
  all-to-all          (n-1)/n   · tensor_bytes
  collective-permute  1         · tensor_bytes

where n = replica-group size parsed from the op, and tensor shapes in the
post-SPMD module are already per-device.  `links` assumes each collective
runs over the torus links of its mesh axis (2 links/axis on a v5e 2D ring).
"""
from __future__ import annotations

import dataclasses
import json
import re


from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  "bf16[16,256,5120]{2,1,0}"  or  "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    op_bytes: dict            # op kind -> Σ per-device wire bytes
    op_counts: dict           # op kind -> #ops
    wire_bytes: float         # total per-device wire bytes

    def to_dict(self):
        return {"wire_bytes": self.wire_bytes, "op_bytes": self.op_bytes,
                "op_counts": self.op_counts}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    op_bytes: dict[str, float] = {}
    op_counts: dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:
            continue  # async pair: count the -start only
        nbytes = _shape_bytes(shape_str)
        # group size: explicit lists or iota [n,g] form
        n = 0
        gm = _GROUPS_RE.search(line)
        if gm:
            first = gm.group(1).split("}")[0].split("{")[-1]
            n = len([t for t in first.split(",") if t.strip() != ""])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))
        n = max(n, 2)
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            wire = (n - 1) / n * nbytes
        else:  # collective-permute
            wire = float(nbytes)
        op_bytes[kind] = op_bytes.get(kind, 0.0) + wire
        op_counts[kind] = op_counts.get(kind, 0) + 1
    return CollectiveStats(op_bytes, op_counts,
                           sum(op_bytes.values()))


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float           # 6·N·D (or 6·N_active·D) global
    peak_memory_bytes: int
    collectives: dict
    notes: str = ""

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound step time:
        (useful model FLOPs / step_time) / (chips × peak)."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return (self.model_flops / t) / (self.chips * PEAK_FLOPS_BF16)

    def to_dict(self):
        return {
            **dataclasses.asdict(self),
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def analyze(compiled, *, arch: str, shape_name: str, mesh_desc: str,
            chips: int, model_flops: float, links_per_axis: int = 2,
            notes: str = "") -> Roofline:
    """Roofline terms via the loop-aware HLO walker.

    NOTE: compiled.cost_analysis() counts while-loop bodies ONCE (verified:
    a 10-step scan reports 1/10 the flops of its unrolled form), so all
    three terms come from repro.roofline.hlo_cost, which multiplies through
    `known_trip_count`.  cost_analysis values are retained in `collectives`
    metadata for reference only.
    """
    from . import hlo_cost
    hlo = compiled.as_text()
    totals = hlo_cost.analyze_hlo(hlo)
    flops = totals.flops
    byts = totals.traffic_bytes
    coll = CollectiveStats(
        op_bytes=dict(totals.collective_bytes),
        op_counts={k: int(v) for k, v in totals.collective_counts.items()},
        wire_bytes=totals.wire_bytes)
    mem = compiled.memory_analysis()
    peak = int(getattr(mem, "temp_size_in_bytes", 0)
               + getattr(mem, "argument_size_in_bytes", 0)
               + getattr(mem, "output_size_in_bytes", 0)
               - getattr(mem, "alias_size_in_bytes", 0))
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_desc, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        wire_bytes_per_device=coll.wire_bytes,
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=byts / HBM_BW,
        collective_s=coll.wire_bytes / (ICI_BW_PER_LINK * links_per_axis),
        model_flops=model_flops,
        peak_memory_bytes=peak,
        collectives=coll.to_dict(),
        notes=notes,
    )


def model_flops_for(cfg, shape) -> float:
    """Useful-FLOPs yardstick: 6·N·D train, 2·N·D inference (per fwd)."""
    n = cfg.active_param_count()
    toks = shape.seq_len * shape.global_batch
    if shape.kind == "train":
        return 6.0 * n * toks
    if shape.kind == "prefill":
        return 2.0 * n * toks
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def save(r: Roofline, path: str) -> None:
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=2)
