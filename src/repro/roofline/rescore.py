"""Re-derive roofline terms from cached HLO (no recompilation).

The dry-run caches every cell's optimized HLO under experiments/hlo/; when
the cost MODEL improves (hlo_cost.py), this tool recomputes all three terms
and rewrites the JSON records in place.

Usage:  PYTHONPATH=src python -m repro.roofline.rescore [--dirs d1 d2 ...]
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

from . import hlo_cost


def rescore_one(json_path: str, hlo_dir: str) -> bool:
    cell = os.path.basename(json_path)[:-5]
    hlo_path = os.path.join(hlo_dir, f"{cell}.hlo.gz")
    if not os.path.exists(hlo_path):
        return False
    with open(json_path) as f:
        r = json.load(f)
    with gzip.open(hlo_path, "rt") as f:
        hlo = f.read()
    t = hlo_cost.analyze_hlo(hlo)
    r["flops_per_device"] = t.flops
    r["bytes_per_device"] = t.traffic_bytes
    r["wire_bytes_per_device"] = t.wire_bytes
    r["compute_s"] = t.flops / PEAK_FLOPS_BF16
    r["memory_s"] = t.traffic_bytes / HBM_BW
    r["collective_s"] = t.wire_bytes / (ICI_BW_PER_LINK * 2)
    r["collectives"] = {
        "wire_bytes": t.wire_bytes,
        "op_bytes": t.collective_bytes,
        "op_counts": {k: int(v) for k, v in t.collective_counts.items()},
    }
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    r["bottleneck"] = max(terms, key=terms.get)
    r["step_time_s"] = max(terms.values())
    total = t.flops * r["chips"]
    r["useful_flops_ratio"] = r["model_flops"] / total if total else 0.0
    r["roofline_fraction"] = (
        (r["model_flops"] / r["step_time_s"]) / (r["chips"] * PEAK_FLOPS_BF16)
        if r["step_time_s"] > 0 else 0.0)
    with open(json_path, "w") as f:
        json.dump(r, f, indent=2)
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dirs", nargs="*",
                    default=["experiments/dryrun", "experiments/perf"])
    args = ap.parse_args()
    n = 0
    for d in args.dirs:
        hlo_dir = os.path.join(os.path.dirname(d.rstrip("/")), "hlo")
        for jp in glob.glob(os.path.join(d, "*.json")):
            if rescore_one(jp, hlo_dir):
                n += 1
    print(f"rescored {n} cells")


if __name__ == "__main__":
    main()
