"""HLO cost walker: loop-aware FLOPs / HBM-traffic / collective-bytes.

``compiled.cost_analysis()`` counts each while-loop BODY ONCE (verified
empirically: a 10-iteration scan reports 1/10 the flops of its unrolled
form), which breaks roofline math for scan-over-layers models.  This module
re-derives the three roofline inputs by walking the optimized HLO text:

* parse every computation (ENTRY, while bodies/conditions, fusions);
* walk from ENTRY, multiplying by `known_trip_count` at each while;
* FLOPs: 2·prod(out_dims)·prod(contracting_dims) per `dot`;
* HBM traffic: Σ (output + operand bytes) over *materializing* top-level
  instructions (fusion internals excluded — they live in registers/VMEM;
  parameter/constant/gte/tuple/bitcast excluded — views, not traffic);
* collective wire bytes with ring-cost factors (see roofline.analysis).

This is a static model: elementwise FLOPs are ignored (≪ matmul terms) and
traffic is an upper-ish bound (fusion boundaries on TPU differ from CPU).
Both caveats are recorded in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?\s*:\s*"?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elem_count(shape_str: str) -> int:
    n = 1
    for d in _first_dims(shape_str):
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: list[str]
    tail: str            # attributes after the operand list
    is_root: bool


@dataclasses.dataclass
class Computation:
    name: str
    params: dict         # name -> shape str
    instrs: list
    shapes: dict         # name -> shape str (params + instr outputs)


def _split_top(s: str) -> list[str]:
    """Split on commas at paren/brace depth 0."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _split_call(rest: str) -> tuple[str, str]:
    """rest = everything after 'op(' -> (operand_str, tail_after_close)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_module(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            h = _HEADER_RE.match(line)
            if h and line.endswith("{"):
                name = h.group(2)
                params = {}
                for part in _split_top(h.group(3)):
                    part = part.strip()
                    if not part:
                        continue
                    pname, _, pshape = part.partition(":")
                    params[pname.strip().lstrip("%")] = pshape.strip()
                cur = Computation(name, params, [], dict(params))
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        is_root, name, shape, op, rest = m.groups()
        opers_str, tail = _split_call(rest)
        opers = [o.strip().lstrip("%").split(" ")[-1].lstrip("%")
                 for o in _split_top(opers_str) if o.strip()]
        instr = Instr(name=name, shape=shape.strip(), op=op,
                      operands=opers, tail=tail, is_root=bool(is_root))
        cur.instrs.append(instr)
        cur.shapes[name] = instr.shape
    return comps


_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id", "iota",
               # control-flow ops themselves move nothing; their bodies'
               # instructions account for per-iteration reads/writes
               "while", "conditional", "call"}

# Ops the TPU compiler reliably fuses into their producers/consumers.  The
# CPU HLO we analyze leaves many of these at top level (weaker fusion), so
# counting their operand+output bytes would overstate HBM traffic ~5-10x
# versus the TPU target.  Their cost is attributed to the anchor ops
# (dot/fusion/reduce/slice/DUS/copy/...) that bound real fusion clusters.
_TPU_FUSABLE = {"add", "subtract", "multiply", "divide", "negate", "abs",
                "exponential", "log", "rsqrt", "sqrt", "tanh", "maximum",
                "minimum", "compare", "select", "and", "or", "not", "xor",
                "convert", "broadcast", "reshape", "clamp", "sign",
                "exponential-minus-one", "log-plus-one", "power", "floor",
                "ceil", "round-nearest-afz", "is-finite", "reverse",
                "concatenate", "pad", "logistic"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_elems = 1
    for d in _first_dims(instr.shape):
        out_elems *= d
    lhs_shape = comp.shapes.get(instr.operands[0], "")
    dims = _first_dims(lhs_shape)
    m = _DOT_DIMS_RE.search(instr.tail)
    contract = 1
    if m and dims:
        for idx in m.group(1).split(","):
            if idx != "" and int(idx) < len(dims):
                contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def _group_size(tail: str) -> int:
    gm = _GROUPS_RE.search(tail)
    if gm:
        first = gm.group(1).split("}")[0].split("{")[-1]
        n = len([t for t in first.split(",") if t.strip() != ""])
        if n:
            return n
    gi = _GROUPS_IOTA_RE.search(tail)
    if gi:
        return int(gi.group(2))
    return 2


def _wire_bytes(instr: Instr, comp: Computation) -> float:
    kind = instr.op.replace("-start", "")
    n = _group_size(instr.tail)
    nbytes = shape_bytes(instr.shape)
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * nbytes
    if kind == "all-gather":
        return (n - 1) / n * nbytes
    if kind == "reduce-scatter":
        # output is the scattered (small) shape; wire ≈ (n-1)·out
        return float(n - 1) * nbytes
    if kind == "all-to-all":
        return (n - 1) / n * nbytes
    return float(nbytes)  # collective-permute


_SLICING = {"dynamic-slice", "slice", "gather"}


def _instr_traffic(comps: dict, comp: Computation, instr: Instr) -> float:
    """HBM bytes moved by one materializing instruction.

    Slicing ops read only their output-sized window of the operand;
    dynamic-update-slice rewrites only the update region (in-place);
    fusions read, per parameter, either the full operand or — when every
    in-fusion use is itself a slicing op — just the sliced windows.
    """
    out = shape_bytes(instr.shape)
    op = instr.op
    if op in _SLICING:
        return 2.0 * out
    if op == "dynamic-update-slice":
        upd = shape_bytes(comp.shapes.get(instr.operands[1], "")) if \
            len(instr.operands) > 1 else out
        return 2.0 * upd
    if op == "scatter":
        # scatter(target, indices, updates): in-place — only the updated
        # elements and the indices move
        upd = shape_bytes(comp.shapes.get(instr.operands[2], "")) if \
            len(instr.operands) > 2 else out
        idx = shape_bytes(comp.shapes.get(instr.operands[1], "")) if \
            len(instr.operands) > 1 else 0
        return 2.0 * upd + idx
    if op == "fusion":
        cm = _CALLS_RE.search(instr.tail)
        called = comps.get(cm.group(1)) if cm else None
        if called is None:
            total = float(out)
            for o in instr.operands:
                total += shape_bytes(comp.shapes.get(o, ""))
            return total
        # pure dtype-cast fusion ("wrapped_convert"): XLA:CPU materializes
        # f32 copies of bf16 weights/activations around dots because the
        # host has no native bf16 matmul; the TPU target computes bf16 on
        # the MXU directly, so these fusions cost nothing there.
        body_ops = {u.op for u in called.instrs} - {"parameter"}
        if body_ops and body_ops <= {"convert", "bitcast", "copy",
                                     "broadcast", "reshape"}:
            return 0.0
        # in-place-update fusion: root is a DUS/scatter whose target aliases
        # the output — the write is the UPDATE region, not the whole buffer.
        # XLA:CPU wraps bf16 DUS/scatter in f32 convert round-trips of the
        # FULL buffer (no native bf16 scatter on CPU); the TPU target
        # scatters bf16 in place, so the convert chain is unwrapped here.
        root = next((u for u in reversed(called.instrs) if u.is_root), None)
        target = root
        while target is not None and target.op == "convert" and \
                target.operands:
            target = next((u for u in called.instrs
                           if u.name == target.operands[0]), None)
        if target is not None and target.op in ("dynamic-update-slice",
                                                "scatter"):
            upd_operand = target.operands[1 if target.op ==
                                          "dynamic-update-slice" else 2]
            upd = shape_bytes(called.shapes.get(upd_operand, ""))
            total = 2.0 * upd
            out_elems = _elem_count(instr.shape)
            # reads of non-aliased operands (skip any with the output's
            # element count — heuristic for the in-place target buffer)
            for o in instr.operands:
                oshape = comp.shapes.get(o, "")
                if _elem_count(oshape) != out_elems:
                    total += shape_bytes(oshape)
            return total
        pnames = list(called.params)
        total = float(out)
        for i, o in enumerate(instr.operands):
            full = shape_bytes(comp.shapes.get(o, ""))
            if i < len(pnames):
                uses = [u for u in called.instrs
                        if pnames[i] in u.operands]
                if uses and all(u.op in _SLICING or
                                (u.op in ("dynamic-update-slice", "scatter")
                                 and u.operands[0] == pnames[i])
                                for u in uses):
                    accessed = 0
                    for u in uses:
                        if u.op in _SLICING:
                            accessed += shape_bytes(u.shape)
                        else:
                            upd_o = u.operands[1 if u.op ==
                                               "dynamic-update-slice" else 2]
                            accessed += shape_bytes(
                                called.shapes.get(upd_o, ""))
                    total += min(full, accessed)
                    continue
            total += full
        return total
    total = float(out)
    for o in instr.operands:
        total += shape_bytes(comp.shapes.get(o, ""))
    return total


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    wire_bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)
    dot_count: int = 0
    while_count: int = 0
    unknown_trip: int = 0


def _walk(comps: dict, name: str, mult: float, in_fusion: bool,
          totals: CostTotals, depth: int = 0) -> None:
    comp = comps.get(name)
    if comp is None or depth > 64:
        return
    for instr in comp.instrs:
        op = instr.op
        base = op.replace("-start", "").replace("-done", "")
        if op.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            wb = _wire_bytes(instr, comp) * mult
            totals.wire_bytes += wb
            totals.collective_bytes[base] = (
                totals.collective_bytes.get(base, 0.0) + wb)
            totals.collective_counts[base] = (
                totals.collective_counts.get(base, 0) + mult)
        if op == "dot":
            totals.flops += _dot_flops(instr, comp) * mult
            totals.dot_count += 1
        if not in_fusion and op not in _NO_TRAFFIC and \
                op not in _TPU_FUSABLE and base not in _COLLECTIVES:
            totals.traffic_bytes += _instr_traffic(comps, comp, instr) * mult
        # recursion
        if op == "while":
            totals.while_count += 1
            tm = _TRIP_RE.search(instr.tail)
            trips = int(tm.group(1)) if tm else 1
            if not tm:
                totals.unknown_trip += 1
            bm = _BODY_RE.search(instr.tail)
            if bm:
                _walk(comps, bm.group(1), mult * trips, in_fusion, totals,
                      depth + 1)
            cm = _COND_RE.search(instr.tail)
            if cm:
                _walk(comps, cm.group(1), mult * trips, True, totals,
                      depth + 1)
        elif op in ("fusion", "call", "custom-call", "map", "reduce",
                    "reduce-window", "scatter", "select-and-scatter", "sort"):
            cm = _CALLS_RE.search(instr.tail)
            if cm:
                _walk(comps, cm.group(1), mult, True, totals, depth + 1)
            # calls={%a, %b} plural form
            for mm in re.finditer(r"to_apply=%?([\w.\-]+)", instr.tail):
                _walk(comps, mm.group(1), mult, True, totals, depth + 1)
        elif op == "conditional":
            bm = _BRANCHES_RE.search(instr.tail)
            if bm:
                for b in bm.group(1).split(","):
                    _walk(comps, b.strip().lstrip("%"), mult, True, totals,
                          depth + 1)


def analyze_hlo(hlo: str) -> CostTotals:
    comps = parse_module(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _HEADER_RE.match(line)
            if m:
                entry = m.group(2)
            break
    totals = CostTotals()
    if entry is None:  # fall back: largest computation
        entry = max(comps, key=lambda n: len(comps[n].instrs))
    _walk(comps, entry, 1.0, False, totals)
    return totals
