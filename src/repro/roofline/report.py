"""Roofline report generator: experiments/dryrun/*.json -> markdown tables.

Usage:  PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
Emits the §Dry-run and §Roofline tables consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, skipped_shapes_for

_SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                "long_500k": 3}


def load(dir_: str) -> list[dict]:
    rows = []
    for path in glob.glob(os.path.join(dir_, "*.json")):
        with open(path) as f:
            r = json.load(f)
        r["_file"] = os.path.basename(path)
        rows.append(r)
    rows.sort(key=lambda r: (ARCHS.index(r["arch"]) if r["arch"] in ARCHS
                             else 99, _SHAPE_ORDER.get(r["shape"], 9),
                             r["mesh"]))
    return rows


def fmt_bytes(n: float) -> str:
    return f"{n/2**30:.2f}"


def dryrun_table(rows: list[dict], mesh_filter: str | None = None) -> str:
    out = ["| arch | shape | mesh | FLOPs/dev | HBM bytes/dev | wire bytes/dev"
           " | peak mem (GiB) | collectives (top) | compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if mesh_filter and mesh_filter not in r["mesh"]:
            continue
        coll = r["collectives"]["op_bytes"]
        top = sorted(coll.items(), key=lambda kv: -kv[1])[:2]
        top_s = " ".join(f"{k}:{v:.1e}" for k, v in top) or "none"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['flops_per_device']:.2e} | {r['bytes_per_device']:.2e} "
            f"| {r['wire_bytes_per_device']:.2e} "
            f"| {fmt_bytes(r['peak_memory_bytes'])} | {top_s} "
            f"| {r.get('compile_s', 0):.1f} |")
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | bottleneck"
           " | useful-FLOPs ratio | roofline frac | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "pod=2" in r["mesh"] or "pod" in r["mesh"].split("x")[0]:
            continue  # roofline table is single-pod per assignment
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | **{r['bottleneck']}** "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']*100:.1f}% | {r['notes']} |")
    for arch in ARCHS:
        for shape, reason in skipped_shapes_for(arch):
            out.append(f"| {arch} | {shape} | — | — | — | — | — | — "
                       f"| {reason} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--table", choices=["dryrun", "roofline", "both"],
                    default="both")
    args = ap.parse_args()
    rows = load(args.dir)
    if args.table in ("dryrun", "both"):
        print("## Dry-run (both meshes)\n")
        print(dryrun_table(rows))
        print()
    if args.table in ("roofline", "both"):
        print("## Roofline (single-pod, 256 chips)\n")
        print(roofline_table(rows))


if __name__ == "__main__":
    main()
