"""Profiler view: top per-op traffic/wire contributors from cached HLO.

Usage: PYTHONPATH=src python -m repro.roofline.top_traffic <cell.hlo.gz> [N]
This is the dry-run 'profile' the hillclimb reads (no hardware timers).
"""
from __future__ import annotations

import gzip
import sys

from . import hlo_cost as hc


def top(path: str, topn: int = 16):
    hlo = gzip.open(path, "rt").read()
    comps = hc.parse_module(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            entry = hc._HEADER_RE.match(line).group(2)
            break
    traffic, wire = [], []

    def walk(name, mult, in_fusion, depth=0):
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        for i in comp.instrs:
            base = i.op.replace("-start", "")
            if i.op.endswith("-done"):
                continue
            if base in hc._COLLECTIVES:
                wire.append((hc._wire_bytes(i, comp) * mult, base,
                             i.shape[:70], mult))
            elif not in_fusion and i.op not in hc._NO_TRAFFIC and \
                    i.op not in hc._TPU_FUSABLE:
                t = hc._instr_traffic(comps, comp, i) * mult
                traffic.append((t, i.op, i.shape[:70], mult,
                                i.tail[-60:] if "metadata" in i.tail else ""))
            if i.op == "while":
                tm = hc._TRIP_RE.search(i.tail)
                trips = int(tm.group(1)) if tm else 1
                bm = hc._BODY_RE.search(i.tail)
                if bm:
                    walk(bm.group(1), mult * trips, in_fusion, depth + 1)
            elif i.op in ("fusion", "call"):
                cm = hc._CALLS_RE.search(i.tail)
                if cm:
                    walk(cm.group(1), mult, True, depth + 1)

    walk(entry, 1.0, False)
    traffic.sort(reverse=True)
    wire.sort(reverse=True)
    print(f"== top HBM traffic ({path}) ==")
    for t in traffic[:topn]:
        print(f"{t[0]/2**30:9.2f} GiB  {t[1]:20s} {t[2]} x{t[3]:.0f}")
    print("== top wire ==")
    for t in wire[:min(topn, 8)]:
        print(f"{t[0]/2**30:9.2f} GiB  {t[1]:20s} {t[2]} x{t[3]:.0f}")


if __name__ == "__main__":
    top(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 16)
