"""Roofline analysis from compiled dry-run artifacts."""
from . import analysis

__all__ = ["analysis"]
