"""Model assembly for all assigned architecture families.

Families:
  dense   — decoder-only LM (GQA/MQA attention + MLP)         [qwen3, minitron,
            granite-34b, qwen2-vl backbone]
  moe     — dense skeleton with MoE FFN                        [grok-1, granite-moe]
  ssm     — attention-free Mamba2 (SSD) stack                  [mamba2-370m]
  hybrid  — Mamba2 backbone + weight-shared attention block
            applied every `period` layers                      [zamba2-2.7b]
  encdec  — Whisper backbone: bidirectional encoder over stub
            frame embeddings + causal decoder w/ cross-attn    [whisper-large-v3]

Layer stacks are `lax.scan` over stacked parameters (compile-time O(1) in
depth — essential for the 40-cell dry-run).  Remat policy wraps the scanned
layer body.  Every family exposes:

  init(key, cfg)                         -> params
  forward(params, batch, cfg, run)       -> (logits, aux)      # train/prefill
  init_cache(cfg, batch, max_seq)        -> cache pytree
  decode_step(params, cache, batch, cfg, run) -> (logits, new_cache)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.act_sharding import constrain

from . import layers as L
from . import mamba2 as M
from . import moe as X


def _dec_attn(run: RunConfig):
    """Decode attention core per RunConfig (direct vs flash-decoding scan)."""
    if run.decode_attn_impl == "chunked":
        return functools.partial(L.decode_attention_chunked,
                                 chunk=run.attention_chunk)
    return L.decode_attention


def _adtype(cfg: ModelConfig):
    return jnp.dtype(cfg.activation_dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _remat(fn, run: RunConfig):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def _angles(cfg: ModelConfig, positions: jax.Array) -> jax.Array | None:
    """positions: [B, S] (or [B, 3, S] for M-RoPE)."""
    if cfg.attn_free:
        return None
    Dh = cfg.resolved_head_dim
    if cfg.mrope:
        if positions.ndim == 2:  # text-only: all three streams identical
            positions = jnp.broadcast_to(positions[:, None, :],
                                         (positions.shape[0], 3,
                                          positions.shape[1]))
        return L.mrope_angles(positions, Dh, cfg.rope_theta,
                              cfg.mrope_sections)
    if positions.ndim == 3:
        positions = positions[:, 0, :]
    return L.rope_angles(positions, Dh, cfg.rope_theta)


# ===========================================================================
# Per-layer init/apply for attention-based layers
# ===========================================================================

def _init_attn_layer(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = _pdtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": L.init_rmsnorm(cfg.d_model, dt),
        "attn": L.init_attention(k1, cfg, dt),
        "ln2": L.init_rmsnorm(cfg.d_model, dt),
    }
    if cfg.family == "moe" or (cfg.moe is not None and cfg.family != "hybrid"):
        p["mlp"] = X.init_moe(k2, cfg, dt)
    else:
        p["mlp"] = L.init_mlp(k2, cfg, dt)
    return p


def _attn_layer_apply(lp: dict, x: jax.Array, cfg: ModelConfig,
                      run: RunConfig, angles, causal: bool):
    h = L.attention_apply(lp["attn"], L.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps),
                          cfg, angles=angles, causal=causal,
                          impl=run.attention_impl, chunk=run.attention_chunk)
    x = x + h
    xn = L.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
    if "router" in lp["mlp"]:
        h2, aux = X.moe_apply(lp["mlp"], xn, cfg,
                              group_size=run.moe_group_size)
    else:
        h2 = L.mlp_apply(lp["mlp"], xn, cfg)
        aux = {}
    return x + h2, aux


def _stack_init(key: jax.Array, n: int, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


# ===========================================================================
# dense / moe decoder-only LM
# ===========================================================================

def init_dense(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kl, kn = jax.random.split(key, 3)
    return {
        "embed": L.init_embedding(ke, cfg, _pdtype(cfg)),
        "layers": _stack_init(kl, cfg.n_layers,
                              lambda k: _init_attn_layer(k, cfg)),
        "final_norm": L.init_rmsnorm(cfg.d_model, _pdtype(cfg)),
    }


def forward_dense(params: dict, batch: dict, cfg: ModelConfig,
                  run: RunConfig, last_only: bool = False):
    tokens = batch["tokens"]                       # [B, S]
    B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = constrain(L.embed_apply(params["embed"], tokens, _adtype(cfg),
                                onehot=cfg.tie_embeddings),
                  "batch", "seq", None)
    ang = _angles(cfg, positions)

    def layer(x, lp):
        x, aux = _attn_layer_apply(lp, x, cfg, run, ang, causal=True)
        return constrain(x, "batch", "seq", None), _aux_vector(aux)

    x, aux_stack = jax.lax.scan(_remat(layer, run), x, params["layers"])
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)
    return logits, _aux_unvector(aux_stack, cfg)


_AUX_KEYS = ("moe_load_balance", "moe_z_loss", "moe_drop_fraction")


def _aux_vector(aux: dict) -> jax.Array:
    return jnp.stack([aux.get(k, jnp.float32(0)) for k in _AUX_KEYS])


def _aux_unvector(aux_stack: jax.Array, cfg: ModelConfig) -> dict:
    sums = aux_stack.sum(axis=0)
    out = dict(zip(_AUX_KEYS, sums))
    if cfg.moe is not None:
        out["moe_drop_fraction"] = out["moe_drop_fraction"] / cfg.n_layers
    return out


# -- decode -----------------------------------------------------------------

def init_cache_dense(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    KH, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_seq, KH, Dh)
    return {
        "k": jnp.zeros(shape, _adtype(cfg)),
        "v": jnp.zeros(shape, _adtype(cfg)),
    }


def _cache_insert(cache: jax.Array, kv: jax.Array, pos: jax.Array):
    """Per-slot scatter write: cache [B,S,KH,Dh], kv [B,1,KH,Dh], pos [B].

    Each sequence writes at ITS OWN position (continuous batching: slots
    join at different lengths).  Inactive slots pass pos >= S and their
    write is dropped (mode="drop") — the in-place scatter never touches
    them.  Lowers to an in-place scatter."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), pos].set(kv[:, 0].astype(cache.dtype),
                                            mode="drop")


def _cache_insert_at_layer(cache_all: jax.Array, kv: jax.Array,
                           layer_idx: jax.Array, pos: jax.Array):
    """Scatter one token's KV into the stacked cache [L,B,S,KH,Dh] at
    (layer_idx, b, pos[b]) — used when the cache rides in a scan carry."""
    B = cache_all.shape[1]
    lidx = jnp.broadcast_to(layer_idx, (B,))
    return cache_all.at[lidx, jnp.arange(B), pos].set(
        kv[:, 0].astype(cache_all.dtype), mode="drop")


def _active_pos(batch: dict, max_seq: int) -> jax.Array:
    """Write positions with inactive slots pushed out of range (dropped)."""
    seq_lens = batch["seq_lens"]
    active = batch.get("active")
    if active is None:
        return seq_lens
    return jnp.where(active, seq_lens, max_seq)


def _masked_state(new: jax.Array, old: jax.Array, active) -> jax.Array:
    """Recurrent-state update gate: inactive slots keep their old state
    (a lockstep decode step must not advance slots that are not decoding
    this tick — double-advancing corrupts SSM recurrences)."""
    if active is None:
        return new
    mask = active.reshape((active.shape[0],) + (1,) * (new.ndim - 1))
    return jnp.where(mask, new, old)


def decode_dense(params: dict, cache: dict, batch: dict, cfg: ModelConfig,
                 run: RunConfig):
    """One decode step.  batch: tokens [B,1], seq_lens [B] i32 (tokens
    already in each slot's cache).  Returns (logits [B, V], new_cache)."""
    tokens = batch["tokens"]
    seq_lens = batch["seq_lens"]                   # [B]: per-slot position
    B = tokens.shape[0]
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                       onehot=cfg.tie_embeddings)
    positions = seq_lens[:, None].astype(jnp.int32)
    ang = _angles(cfg, positions)

    wpos = _active_pos(batch, cache["k"].shape[2])
    H, Dh = cfg.n_heads, cfg.resolved_head_dim

    def _ffn(x, lp):
        xn = L.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
        if "router" in lp["mlp"]:
            h2, _ = X.moe_apply(lp["mlp"], xn, cfg,
                                group_size=run.moe_group_size)
        else:
            h2 = L.mlp_apply(lp["mlp"], xn, cfg)
        return x + h2

    if run.decode_carry_cache:
        # OPT: thread the stacked cache through the scan CARRY.  With the
        # xs->ys formulation XLA materializes a second full-size cache
        # buffer (the stacked ys) — the whole KV cache is copied every
        # decode step.  A loop carry is updated in place; only the new
        # token's KV is written.  (EXPERIMENTS.md §Perf, cell C.)
        def layer(carry, inputs):
            x, kc_all, vc_all = carry              # [L, B, S, KH, Dh]
            lp, l = inputs
            xn = L.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps)
            q, k, v = L.attention_qkv(lp["attn"], xn, cfg, ang)
            kc_all = _cache_insert_at_layer(kc_all, k, l, wpos)
            vc_all = _cache_insert_at_layer(vc_all, v, l, wpos)
            o = _dec_attn(run)(q[:, 0], kc_all[l], vc_all[l],
                               seq_lens[:, None] + 1)
            x = x + (o.reshape(B, 1, H * Dh) @ lp["attn"]["wo"])
            return (_ffn(x, lp), kc_all, vc_all), None

        (x, k_new, v_new), _ = jax.lax.scan(
            layer, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(cfg.n_layers)))
    else:
        def layer(x, inputs):
            lp, kc, vc = inputs                    # kc/vc: [B, S, KH, Dh]
            xn = L.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps)
            q, k, v = L.attention_qkv(lp["attn"], xn, cfg, ang)
            kc = _cache_insert(kc, k, wpos)
            vc = _cache_insert(vc, v, wpos)
            o = _dec_attn(run)(q[:, 0], kc, vc, seq_lens[:, None] + 1)
            x = x + (o.reshape(B, 1, H * Dh) @ lp["attn"]["wo"])
            return _ffn(x, lp), (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            layer, x, (params["layers"], cache["k"], cache["v"]))

    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)[:, 0]
    return logits, {"k": k_new, "v": v_new}


# ===========================================================================
# ssm (Mamba2)
# ===========================================================================

def init_ssm(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kl = jax.random.split(key)
    dt = _pdtype(cfg)

    def one(k):
        return {"ln": L.init_rmsnorm(cfg.d_model, dt),
                "mixer": M.init_mamba2(k, cfg, dt)}

    return {
        "embed": L.init_embedding(ke, cfg, dt),
        "layers": _stack_init(kl, cfg.n_layers, one),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }


def forward_ssm(params: dict, batch: dict, cfg: ModelConfig, run: RunConfig,
                last_only: bool = False):
    tokens = batch["tokens"]
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                       onehot=cfg.tie_embeddings)
    impl = "pallas" if run.attention_impl == "pallas" else "chunked"

    def layer(x, lp):
        h = M.mamba2_apply(lp["mixer"],
                           L.rmsnorm_apply(lp["ln"], x, cfg.norm_eps),
                           cfg, impl=impl)
        return x + h, jnp.float32(0)

    x, _ = jax.lax.scan(_remat(layer, run), x, params["layers"])
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return L.unembed_apply(params["embed"], x), {}


def init_cache_ssm(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dm = M.ssm_dims(cfg)
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, dm["nheads"], dm["state"],
                          dm["head_dim"]), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, dm["conv_width"] - 1,
                           dm["conv_dim"]), _adtype(cfg)),
    }


def decode_ssm(params: dict, cache: dict, batch: dict, cfg: ModelConfig,
               run: RunConfig):
    tokens = batch["tokens"]
    active = batch.get("active")
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                       onehot=cfg.tie_embeddings)

    def layer(x, inputs):
        lp, ssm_state, conv_state = inputs
        h, ssm_new, conv_new = M.mamba2_decode(
            lp["mixer"], L.rmsnorm_apply(lp["ln"], x, cfg.norm_eps), cfg,
            ssm_state, conv_state)
        return x + h, (_masked_state(ssm_new, ssm_state, active),
                       _masked_state(conv_new, conv_state, active))

    x, (ssm_new, conv_new) = jax.lax.scan(
        layer, x, (params["layers"], cache["ssm"], cache["conv"]))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)[:, 0]
    return logits, {"ssm": ssm_new, "conv": conv_new}


# ===========================================================================
# hybrid (Zamba2): Mamba2 backbone + shared attention block every `period`
# ===========================================================================

def _hybrid_groups(cfg: ModelConfig) -> tuple[int, int]:
    period = cfg.hybrid.period if cfg.hybrid else 6
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    return cfg.n_layers // period, period


def init_hybrid(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kl, ks = jax.random.split(key, 3)
    dt = _pdtype(cfg)
    n_groups, period = _hybrid_groups(cfg)

    def one(k):
        return {"ln": L.init_rmsnorm(cfg.d_model, dt),
                "mixer": M.init_mamba2(k, cfg, dt)}

    return {
        "embed": L.init_embedding(ke, cfg, dt),
        "layers": _stack_init(kl, cfg.n_layers, one),   # [L, ...]
        "shared": _init_attn_layer(ks, cfg),            # weight-tied block
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }


def _group_params(params: dict, cfg: ModelConfig):
    """Reshape stacked mamba layers [L, ...] -> [G, period, ...]."""
    n_groups, period = _hybrid_groups(cfg)
    return jax.tree.map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]),
        params["layers"])


def forward_hybrid(params: dict, batch: dict, cfg: ModelConfig,
                   run: RunConfig, last_only: bool = False):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                       onehot=cfg.tie_embeddings)
    ang = _angles(cfg, positions)
    shared = params["shared"]
    impl = "pallas" if run.attention_impl == "pallas" else "chunked"

    def mamba_layer(x, lp):
        h = M.mamba2_apply(lp["mixer"],
                           L.rmsnorm_apply(lp["ln"], x, cfg.norm_eps),
                           cfg, impl=impl)
        return x + h, None

    def group(x, glp):
        x, _ = jax.lax.scan(mamba_layer, x, glp)
        x, _ = _attn_layer_apply(shared, x, cfg, run, ang, causal=True)
        return x, None

    x, _ = jax.lax.scan(_remat(group, run), x, _group_params(params, cfg))
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return L.unembed_apply(params["embed"], x), {}


def init_cache_hybrid(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    dm = M.ssm_dims(cfg)
    n_groups, _ = _hybrid_groups(cfg)
    KH, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, dm["nheads"], dm["state"],
                          dm["head_dim"]), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, dm["conv_width"] - 1,
                           dm["conv_dim"]), _adtype(cfg)),
        # one KV cache per shared-block invocation
        "k": jnp.zeros((n_groups, batch, max_seq, KH, Dh), _adtype(cfg)),
        "v": jnp.zeros((n_groups, batch, max_seq, KH, Dh), _adtype(cfg)),
    }


def decode_hybrid(params: dict, cache: dict, batch: dict, cfg: ModelConfig,
                  run: RunConfig):
    tokens = batch["tokens"]
    seq_lens = batch["seq_lens"]
    B = tokens.shape[0]
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                       onehot=cfg.tie_embeddings)
    positions = seq_lens[:, None].astype(jnp.int32)
    ang = _angles(cfg, positions)
    shared = params["shared"]
    n_groups, period = _hybrid_groups(cfg)

    active = batch.get("active")
    wpos = _active_pos(batch, cache["k"].shape[2])

    def mamba_layer(x, inputs):
        lp, ssm_state, conv_state = inputs
        h, ssm_new, conv_new = M.mamba2_decode(
            lp["mixer"], L.rmsnorm_apply(lp["ln"], x, cfg.norm_eps), cfg,
            ssm_state, conv_state)
        return x + h, (_masked_state(ssm_new, ssm_state, active),
                       _masked_state(conv_new, conv_state, active))

    def group(x, inputs):
        glp, ssm_g, conv_g, kc, vc = inputs
        x, (ssm_g, conv_g) = jax.lax.scan(mamba_layer, x, (glp, ssm_g, conv_g))
        xn = L.rmsnorm_apply(shared["ln1"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(shared["attn"], xn, cfg, ang)
        kc = _cache_insert(kc, k, wpos)
        vc = _cache_insert(vc, v, wpos)
        o = _dec_attn(run)(q[:, 0], kc, vc, seq_lens[:, None] + 1)
        H, Dh = cfg.n_heads, cfg.resolved_head_dim
        x = x + (o.reshape(B, 1, H * Dh) @ shared["attn"]["wo"])
        xn = L.rmsnorm_apply(shared["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(shared["mlp"], xn, cfg)
        return x, (ssm_g, conv_g, kc, vc)

    glp = _group_params(params, cfg)
    ssm_g = cache["ssm"].reshape((n_groups, period) + cache["ssm"].shape[1:])
    conv_g = cache["conv"].reshape((n_groups, period) + cache["conv"].shape[1:])
    x, (ssm_new, conv_new, k_new, v_new) = jax.lax.scan(
        group, x, (glp, ssm_g, conv_g, cache["k"], cache["v"]))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)[:, 0]
    return logits, {
        "ssm": ssm_new.reshape(cache["ssm"].shape),
        "conv": conv_new.reshape(cache["conv"].shape),
        "k": k_new, "v": v_new,
    }


# ===========================================================================
# encdec (Whisper backbone; conv/mel frontend is a stub per assignment)
# ===========================================================================

def _init_encdec_dec_layer(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = _pdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model, dt),
        "attn": L.init_attention(k1, cfg, dt),
        "ln_x": L.init_rmsnorm(cfg.d_model, dt),
        "xattn": L.init_attention(k2, cfg, dt),
        "ln2": L.init_rmsnorm(cfg.d_model, dt),
        "mlp": L.init_mlp(k3, cfg, dt),
    }


def init_encdec(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kenc, kdec, kp = jax.random.split(key, 4)
    dt = _pdtype(cfg)
    enc_layers = cfg.encoder_layers or cfg.n_layers
    return {
        "embed": L.init_embedding(ke, cfg, dt),
        # learned positional embedding for encoder frames (whisper-style)
        "enc_pos": (jax.random.normal(kp, (cfg.encoder_seq, cfg.d_model))
                    * 0.01).astype(dt),
        "encoder": _stack_init(kenc, enc_layers,
                               lambda k: _init_attn_layer(k, cfg)),
        "enc_norm": L.init_rmsnorm(cfg.d_model, dt),
        "decoder": _stack_init(kdec, cfg.n_layers,
                               lambda k: _init_encdec_dec_layer(k, cfg)),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }


def encode(params: dict, frames: jax.Array, cfg: ModelConfig,
           run: RunConfig) -> jax.Array:
    """frames: [B, F, D] precomputed frame embeddings (conv frontend STUB)."""
    x = frames.astype(_adtype(cfg)) + params["enc_pos"][None, :frames.shape[1]]

    def layer(x, lp):
        x, _ = _attn_layer_apply(lp, x, cfg, run, angles=None, causal=False)
        return x, None

    x, _ = jax.lax.scan(_remat(layer, run), x, params["encoder"])
    return L.rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)


def forward_encdec(params: dict, batch: dict, cfg: ModelConfig,
                   run: RunConfig, last_only: bool = False):
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(params, batch["frames"], cfg, run)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    ang = _angles(cfg, positions)
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                       onehot=cfg.tie_embeddings)

    def layer(x, lp):
        xn = L.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps)
        x = x + L.attention_apply(lp["attn"], xn, cfg, angles=ang, causal=True,
                                  impl=run.attention_impl,
                                  chunk=run.attention_chunk)
        xn = L.rmsnorm_apply(lp["ln_x"], x, cfg.norm_eps)
        # cross-attention: KV from encoder output (no rope)
        kx = (enc_out @ lp["xattn"]["wk"]).reshape(
            B, enc_out.shape[1], cfg.n_kv_heads, cfg.resolved_head_dim)
        vx = (enc_out @ lp["xattn"]["wv"]).reshape(
            B, enc_out.shape[1], cfg.n_kv_heads, cfg.resolved_head_dim)
        q = (xn @ lp["xattn"]["wq"]).reshape(
            B, S, cfg.n_heads, cfg.resolved_head_dim)
        o = L.chunked_attention(q, kx, vx, causal=False,
                                chunk=run.attention_chunk)
        x = x + (o.reshape(B, S, -1) @ lp["xattn"]["wo"])
        xn = L.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], xn, cfg)
        return x, None

    x, _ = jax.lax.scan(_remat(layer, run), x, params["decoder"])
    if last_only:
        x = x[:, -1:]
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return L.unembed_apply(params["embed"], x), {}


def init_cache_encdec(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    KH, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    F = cfg.encoder_seq
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, KH, Dh), _adtype(cfg)),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, KH, Dh), _adtype(cfg)),
        # precomputed cross-attention KV (from the encoder pass)
        "xk": jnp.zeros((cfg.n_layers, batch, F, KH, Dh), _adtype(cfg)),
        "xv": jnp.zeros((cfg.n_layers, batch, F, KH, Dh), _adtype(cfg)),
    }


def precompute_cross_kv(params: dict, enc_out: jax.Array, cfg: ModelConfig):
    """enc_out: [B, F, D] -> (xk, xv): [Ldec, B, F, KH, Dh]."""
    B, F, _ = enc_out.shape
    KH, Dh = cfg.n_kv_heads, cfg.resolved_head_dim

    def one(lp):
        xk = (enc_out @ lp["xattn"]["wk"]).reshape(B, F, KH, Dh)
        xv = (enc_out @ lp["xattn"]["wv"]).reshape(B, F, KH, Dh)
        return xk, xv

    return jax.vmap(one)(params["decoder"])


def decode_encdec(params: dict, cache: dict, batch: dict, cfg: ModelConfig,
                  run: RunConfig):
    tokens = batch["tokens"]
    seq_lens = batch["seq_lens"]
    B = tokens.shape[0]
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                       onehot=cfg.tie_embeddings)
    positions = seq_lens[:, None].astype(jnp.int32)
    ang = _angles(cfg, positions)
    F = cache["xk"].shape[2]

    wpos = _active_pos(batch, cache["k"].shape[2])

    def layer(x, inputs):
        lp, kc, vc, xk, xv = inputs
        xn = L.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], xn, cfg, ang)
        kc = _cache_insert(kc, k, wpos)
        vc = _cache_insert(vc, v, wpos)
        o = _dec_attn(run)(q[:, 0], kc, vc, seq_lens[:, None] + 1)
        x = x + (o.reshape(B, 1, H * Dh) @ lp["attn"]["wo"])
        # cross attention against precomputed encoder KV
        xn = L.rmsnorm_apply(lp["ln_x"], x, cfg.norm_eps)
        qx = (xn @ lp["xattn"]["wq"]).reshape(B, 1, H, Dh)
        ox = L.decode_attention(qx[:, 0], xk, xv, F)
        x = x + (ox.reshape(B, 1, H * Dh) @ lp["xattn"]["wo"])
        xn = L.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], xn, cfg)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        layer, x, (params["decoder"], cache["k"], cache["v"],
                   cache["xk"], cache["xv"]))
    x = L.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)[:, 0]
    return logits, {"k": k_new, "v": v_new, "xk": cache["xk"],
                    "xv": cache["xv"]}


# ===========================================================================
# Family dispatch
# ===========================================================================

_FAMILY = {
    "dense": (init_dense, forward_dense, init_cache_dense, decode_dense),
    "moe": (init_dense, forward_dense, init_cache_dense, decode_dense),
    "ssm": (init_ssm, forward_ssm, init_cache_ssm, decode_ssm),
    "hybrid": (init_hybrid, forward_hybrid, init_cache_hybrid, decode_hybrid),
    "encdec": (init_encdec, forward_encdec, init_cache_encdec, decode_encdec),
}


def init(key: jax.Array, cfg: ModelConfig) -> dict:
    return _FAMILY[cfg.family][0](key, cfg)


def forward(params: dict, batch: dict, cfg: ModelConfig, run: RunConfig,
            last_only: bool = False):
    return _FAMILY[cfg.family][1](params, batch, cfg, run, last_only=last_only)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return _FAMILY[cfg.family][2](cfg, batch, max_seq)


def decode_step(params: dict, cache: dict, batch: dict, cfg: ModelConfig,
                run: RunConfig):
    return _FAMILY[cfg.family][3](params, cache, batch, cfg, run)


# ===========================================================================
# Serving prefill: forward pass that also materializes the decode cache
# ===========================================================================

def _last_hidden(x: jax.Array, batch: dict) -> jax.Array:
    """Select the true last-prompt position per sequence.

    Prompts may be right-padded to a bucket length; `last_index` [B] gives
    each sequence's final real position (default: the last column)."""
    idx = batch.get("last_index")
    if idx is None:
        return x[:, -1:]
    B = x.shape[0]
    return x[jnp.arange(B), idx][:, None]


def _pad_seq(arr: jax.Array, max_seq: int, axis: int = 2) -> jax.Array:
    """Pad the seq axis of collected KV [L, B, S, KH, Dh] out to max_seq."""
    pad = [(0, 0)] * arr.ndim
    pad[axis] = (0, max_seq - arr.shape[axis])
    return jnp.pad(arr, pad)


def prefill_dense_with_cache(params: dict, batch: dict, cfg: ModelConfig,
                             run: RunConfig, max_seq: int):
    """Returns (last_logits [B, V], cache) — dense/moe families."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                      onehot=cfg.tie_embeddings)
    ang = _angles(cfg, positions)
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    def layer(x, lp):
        xn = L.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], xn, cfg, ang)
        if run.attention_impl == "naive":
            o = L.naive_attention(q, k, v, causal=True)
        else:
            o = L.chunked_attention(q, k, v, causal=True,
                                    chunk=run.attention_chunk)
        x = x + (o.reshape(B, S, H * Dh) @ lp["attn"]["wo"])
        xn = L.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
        if "router" in lp["mlp"]:
            h2, _ = X.moe_apply(lp["mlp"], xn, cfg,
                                group_size=run.moe_group_size)
        else:
            h2 = L.mlp_apply(lp["mlp"], xn, cfg)
        return x + h2, (k.astype(_adtype(cfg)), v.astype(_adtype(cfg)))

    x, (ks, vs) = jax.lax.scan(layer, x, params["layers"])
    x = L.rmsnorm_apply(params["final_norm"], _last_hidden(x, batch),
                        cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)[:, 0]
    cache = {"k": _pad_seq(ks, max_seq), "v": _pad_seq(vs, max_seq)}
    return logits, cache


def prefill_ssm_with_cache(params: dict, batch: dict, cfg: ModelConfig,
                           run: RunConfig, max_seq: int):
    tokens = batch["tokens"]
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                      onehot=cfg.tie_embeddings)
    impl = "pallas" if run.attention_impl == "pallas" else "chunked"

    def layer(x, lp):
        h, (ssm_state, conv_state) = M.mamba2_apply(
            lp["mixer"], L.rmsnorm_apply(lp["ln"], x, cfg.norm_eps), cfg,
            impl=impl, return_state=True)
        return x + h, (ssm_state, conv_state)

    x, (ssm_s, conv_s) = jax.lax.scan(layer, x, params["layers"])
    x = L.rmsnorm_apply(params["final_norm"], _last_hidden(x, batch),
                        cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)[:, 0]
    return logits, {"ssm": ssm_s, "conv": conv_s.astype(_adtype(cfg))}


def prefill_hybrid_with_cache(params: dict, batch: dict, cfg: ModelConfig,
                              run: RunConfig, max_seq: int):
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                      onehot=cfg.tie_embeddings)
    ang = _angles(cfg, positions)
    shared = params["shared"]
    n_groups, period = _hybrid_groups(cfg)
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    impl = "pallas" if run.attention_impl == "pallas" else "chunked"

    def mamba_layer(x, lp):
        h, st = M.mamba2_apply(
            lp["mixer"], L.rmsnorm_apply(lp["ln"], x, cfg.norm_eps), cfg,
            impl=impl, return_state=True)
        return x + h, st

    def group(x, glp):
        x, (ssm_g, conv_g) = jax.lax.scan(mamba_layer, x, glp)
        xn = L.rmsnorm_apply(shared["ln1"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(shared["attn"], xn, cfg, ang)
        o = L.chunked_attention(q, k, v, causal=True,
                                chunk=run.attention_chunk)
        x = x + (o.reshape(B, S, H * Dh) @ shared["attn"]["wo"])
        xn = L.rmsnorm_apply(shared["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(shared["mlp"], xn, cfg)
        return x, (ssm_g, conv_g, k.astype(_adtype(cfg)),
                   v.astype(_adtype(cfg)))

    x, (ssm_g, conv_g, ks, vs) = jax.lax.scan(
        group, x, _group_params(params, cfg))
    x = L.rmsnorm_apply(params["final_norm"], _last_hidden(x, batch),
                        cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)[:, 0]
    cache = {
        "ssm": ssm_g.reshape((cfg.n_layers,) + ssm_g.shape[2:]),
        "conv": conv_g.reshape((cfg.n_layers,) + conv_g.shape[2:]).astype(
            _adtype(cfg)),
        "k": _pad_seq(ks, max_seq),
        "v": _pad_seq(vs, max_seq),
    }
    return logits, cache


def prefill_encdec_with_cache(params: dict, batch: dict, cfg: ModelConfig,
                              run: RunConfig, max_seq: int):
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(params, batch["frames"], cfg, run)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    ang = _angles(cfg, positions)
    x = L.embed_apply(params["embed"], tokens, _adtype(cfg),
                      onehot=cfg.tie_embeddings)
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    F = enc_out.shape[1]

    def layer(x, lp):
        xn = L.rmsnorm_apply(lp["ln1"], x, cfg.norm_eps)
        q, k, v = L.attention_qkv(lp["attn"], xn, cfg, ang)
        o = L.chunked_attention(q, k, v, causal=True,
                                chunk=run.attention_chunk)
        x = x + (o.reshape(B, S, H * Dh) @ lp["attn"]["wo"])
        xn = L.rmsnorm_apply(lp["ln_x"], x, cfg.norm_eps)
        kx = (enc_out @ lp["xattn"]["wk"]).reshape(B, F, KH, Dh)
        vx = (enc_out @ lp["xattn"]["wv"]).reshape(B, F, KH, Dh)
        qx = (xn @ lp["xattn"]["wq"]).reshape(B, S, H, Dh)
        ox = L.chunked_attention(qx, kx, vx, causal=False,
                                 chunk=run.attention_chunk)
        x = x + (ox.reshape(B, S, H * Dh) @ lp["xattn"]["wo"])
        xn = L.rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], xn, cfg)
        return x, (k.astype(_adtype(cfg)), v.astype(_adtype(cfg)),
                   kx.astype(_adtype(cfg)), vx.astype(_adtype(cfg)))

    x, (ks, vs, xks, xvs) = jax.lax.scan(layer, x, params["decoder"])
    x = L.rmsnorm_apply(params["final_norm"], _last_hidden(x, batch),
                        cfg.norm_eps)
    logits = L.unembed_apply(params["embed"], x)[:, 0]
    cache = {"k": _pad_seq(ks, max_seq), "v": _pad_seq(vs, max_seq),
             "xk": xks, "xv": xvs}
    return logits, cache


_PREFILL_CACHE = {
    "dense": prefill_dense_with_cache,
    "moe": prefill_dense_with_cache,
    "ssm": prefill_ssm_with_cache,
    "hybrid": prefill_hybrid_with_cache,
    "encdec": prefill_encdec_with_cache,
}


def prefill_with_cache(params: dict, batch: dict, cfg: ModelConfig,
                       run: RunConfig, max_seq: int):
    """(last_logits [B, V], decode-ready cache) for every family."""
    return _PREFILL_CACHE[cfg.family](params, batch, cfg, run, max_seq)
