"""Mixture-of-Experts FFN: top-k router + GShard-style grouped dispatch.

Tokens are partitioned into fixed-size GROUPS (GShard/Switch "expert group
size"), each with its own capacity C = ceil(S·k/E·factor).  This keeps the
dispatch tensors at [G, S, E, C] with S ≈ 2k instead of a single global
[T, E, C] whose capacity grows with T — the global form is O(T²) memory and
exploded at prefill scale (T = 1M ⇒ C = 256k).  The group dim carries the
batch sharding, so routing is local to each data shard and the expert
einsums lower to expert-parallel collectives when experts are sharded.

    dispatch [G,S,E,C] (bf16 0/1) · x [G,S,D] -> [G,E,C,D]   (a2a/scatter)
    expert FFN on [E, G·C, D]                                 (local compute)
    combine  [G,S,E,C] (bf16, gate-scaled) · y -> out         (a2a/gather)

Aux losses (Switch load-balance + router z-loss) are averaged over groups.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import constrain


def init_moe(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    params = {
        "router": (jax.random.normal(k1, (d, e)) * s_in).astype(jnp.float32),
        "w_up": (jax.random.normal(k3, (e, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k4, (e, f, d)) * s_out).astype(dtype),
    }
    if cfg.act == "silu":
        params["w_gate"] = (jax.random.normal(k2, (e, d, f)) * s_in).astype(dtype)
    return params


GROUP_SIZE = 2048  # default GShard expert-group size


def moe_group_shape(n_tokens: int, group_size: int = GROUP_SIZE) -> tuple[int, int]:
    """(n_groups, group_size) with group_size | n_tokens."""
    s = min(group_size, n_tokens)
    while n_tokens % s:
        s //= 2
    return n_tokens // s, max(s, 1)


def moe_capacity(group_size: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    cap = int(math.ceil(group_size * m.top_k / m.num_experts
                        * m.capacity_factor))
    return max(8, -(-cap // 8) * 8)


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig,
              group_size: int = GROUP_SIZE) -> tuple:
    """x: [B, S, D] -> (out [B, S, D], aux: dict of scalar losses)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    Gp, Sg = moe_group_shape(T, group_size)
    C = moe_capacity(Sg, cfg)
    xg = x.reshape(Gp, Sg, D)
    xg = constrain(xg, "batch", None, None)

    logits = (xg.astype(jnp.float32) @ params["router"])        # [G, S, E]
    probs = jax.nn.softmax(logits, axis=-1)

    # --- top-k selection with per-group capacity positions -------------------
    # Lean integer/boolean routing: every intermediate is bool/i32 and the
    # only [G,S,E,C]-sized tensors are the bf16 dispatch/combine masks
    # themselves.  (The textbook f32 one-hot formulation materializes
    # [G,S,K,C] and [G,S,K,E] float tensors — measured 4x the HBM traffic
    # of the experts; EXPERIMENTS.md §Perf cell A.)
    topk_probs, topk_idx = jax.lax.top_k(probs, K)              # [G, S, K]
    topk_probs = topk_probs / jnp.maximum(
        topk_probs.sum(axis=-1, keepdims=True), 1e-9)

    sel = (topk_idx[..., None] ==
           jnp.arange(E, dtype=jnp.int32))                      # [G,S,K,E] bool
    # priority: round-major (1st choices first), token order within a round
    flat = sel.transpose(0, 2, 1, 3).reshape(Gp, K * Sg, E)
    pos_flat = jnp.cumsum(flat.astype(jnp.int32), axis=1) - flat
    pos = pos_flat.reshape(Gp, K, Sg, E).transpose(0, 2, 1, 3)  # [G,S,K,E] i32
    within = (pos < C) & sel                                    # bool
    kept = within.any(-1)                                       # [G, S, K] bool
    # per-(token, expert) slot: E-reduction of the K selection tensors
    pos_e = jnp.where(within, pos, 0).sum(2)                    # [G, S, E] i32
    sel_e = within.any(2)                                       # [G, S, E] bool
    gate_e = jnp.where(
        sel_e, jnp.einsum("gske,gsk->gse", within.astype(jnp.float32),
                          topk_probs), 0.0)                     # [G, S, E] f32

    c_iota = jnp.arange(C, dtype=jnp.int32)
    slot_hit = sel_e[..., None] & (pos_e[..., None] == c_iota)  # [G,S,E,C] bool
    dispatch = slot_hit.astype(x.dtype)                         # bf16 0/1
    combine = jnp.where(slot_hit, gate_e[..., None], 0.0).astype(x.dtype)
    dispatch = constrain(dispatch, "batch", None, "expert", None)
    combine = constrain(combine, "batch", None, "expert", None)

    # --- expert computation ----------------------------------------------------
    xin = jnp.einsum("gsec,gsd->gecd", dispatch, xg)            # [G, E, C, D]
    xin = constrain(xin, "batch", "expert", None, None)
    if cfg.act == "silu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, params["w_gate"])) \
            * jnp.einsum("gecd,edf->gecf", xin, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xin, params["w_up"]))
    h = constrain(h, "batch", "expert", None, "hidden")
    y = jnp.einsum("gecf,efd->gecd", h, params["w_down"])       # [G, E, C, D]
    y = constrain(y, "batch", "expert", None, None)
    out = jnp.einsum("gsec,gecd->gsd", combine, y)              # [G, S, D]

    # --- aux losses --------------------------------------------------------------
    me = probs.mean(axis=1)                                     # [G, E]
    ce = sel[:, :, 0, :].astype(jnp.float32).mean(axis=1)       # [G, E]
    lb = E * jnp.sum(me * ce, axis=-1).mean() * m.load_balance_loss
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_loss
    dropped = 1.0 - kept.astype(jnp.float32).mean()
    aux = {"moe_load_balance": lb, "moe_z_loss": z,
           "moe_drop_fraction": dropped}
    return out.reshape(B, S, D), aux
