"""Mamba2 — SSD (state-space duality) mixer layer (arXiv:2405.21060).

The SSD chunked algorithm in pure JAX (the Pallas kernel in repro.kernels
accelerates the intra-chunk part on TPU):

  per head h, with per-step decay a_t = exp(dt_t * A_h):
    intra-chunk:  Y_ij = C_i·B_j · exp(Σ_{j<r<=i} log a_r) · (dt_j x_j), i>=j
    chunk state:  S_c  = Σ_j exp(Σ_{j<r<=last} log a_r) B_j ⊗ (dt_j x_j)
    inter-chunk:  recurrence S <- decay(chunk) · S + S_c  (lax.scan over chunks)
    output:       y_i += C_i · S_prev · exp(Σ_{r<=i} log a_r)

Decode is the O(1) recurrent update:  S <- a·S + B⊗(dt·x);  y = C·S + D·x.

Layer wiring follows the Mamba2 block: in_proj -> (z, xBC, dt); causal
depthwise conv over xBC; SSD; gated RMSNorm; out_proj.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.distributed.act_sharding import constrain

from .layers import cdiv, init_rmsnorm, rmsnorm_apply


# ---------------------------------------------------------------------------
# Dimensions
# ---------------------------------------------------------------------------

def ssm_dims(cfg: ModelConfig) -> dict:
    s = cfg.ssm or SSMConfig()
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.state_dim
    return {"d_inner": d_in, "nheads": nheads, "conv_dim": conv_dim,
            "state": s.state_dim, "head_dim": s.head_dim,
            "groups": s.n_groups, "conv_width": s.conv_width,
            "chunk": s.chunk_size}


def init_mamba2(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    """Separate z / xBC / dt projections (instead of one fused in_proj) so
    each output dim gets a clean tensor-parallel sharding — a fused matrix
    sliced at non-shard boundaries would force collective-permutes."""
    dm = ssm_dims(cfg)
    d = cfg.d_model
    d_in, nheads, conv_dim = dm["d_inner"], dm["nheads"], dm["conv_dim"]
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d)
    return {
        "w_z": (jax.random.normal(k1, (d, d_in)) * s_in).astype(dtype),
        "w_xBC": (jax.random.normal(k2, (d, conv_dim)) * s_in).astype(dtype),
        "w_dt": (jax.random.normal(k3, (d, nheads)) * s_in).astype(dtype),
        "conv_w": (jax.random.normal(k5, (dm["conv_width"], conv_dim))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "gate_norm": init_rmsnorm(d_in, dtype),
        "out_proj": (jax.random.normal(k4, (d_in, d))
                     / math.sqrt(d_in)).astype(dtype),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: [B, L, C]; w: [W, C] depthwise; left-pad to keep causality."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):  # W is tiny (4); unrolled adds, no gather
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
              b: jax.Array) -> tuple:
    """Decode: x_t [B, C]; conv_state [B, W-1, C] (previous inputs)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [B,W,C]
    out = jnp.einsum("bwc,wc->bc", window, w) + b
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# SSD core (chunked, pure JAX)
# ---------------------------------------------------------------------------

def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bmat: jax.Array,
                Cmat: jax.Array, chunk: int, init_state: jax.Array | None = None):
    """SSD scan.

    x:    [B, L, H, P]  (head inputs)
    dt:   [B, L, H]     (positive step sizes, post-softplus)
    A:    [H]           (negative per-head decay rates)
    Bmat: [B, L, G, N]
    Cmat: [B, L, G, N]
    Returns (y [B, L, H, P], final_state [B, H, P, N]).
    """
    Bsz, L, H, P = x.shape
    G, N = Bmat.shape[2], Bmat.shape[3]
    HperG = H // G
    nchunks = cdiv(L, chunk)
    pad = nchunks * chunk - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = nchunks * chunk

    f32 = jnp.float32
    # reshape to chunks: [B, nc, Q, ...]
    xq = x.reshape(Bsz, nchunks, chunk, H, P).astype(f32)
    dtq = dt.reshape(Bsz, nchunks, chunk, H).astype(f32)
    Bq = Bmat.reshape(Bsz, nchunks, chunk, G, N).astype(f32)
    Cq = Cmat.reshape(Bsz, nchunks, chunk, G, N).astype(f32)

    dA = dtq * A.astype(f32)                         # [B,nc,Q,H] (negative)
    cum = jnp.cumsum(dA, axis=2)                     # inclusive cumsum of log-decay
    seg_total = cum[:, :, -1, :]                     # [B,nc,H]

    xdt = xq * dtq[..., None]                        # dt-weighted inputs

    # ---- intra-chunk (quadratic within chunk) --------------------------------
    # decay from j to i (i>=j): exp(cum_i - cum_j)
    li = cum[:, :, :, None, :]                       # [B,nc,Q,1,H]
    lj = cum[:, :, None, :, :]                       # [B,nc,1,Q,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # clamp BEFORE exp: masked (i<j) entries have li-lj > 0 and can overflow;
    # exp(inf) at masked positions turns the where-vjp into 0·inf = NaN.
    # valid entries always have li-lj <= 0 (cum is non-increasing), so the
    # clamp is exact for them.
    decay = jnp.where(mask, jnp.exp(jnp.minimum(li - lj, 0.0)), 0.0)
    # scores: C_i · B_j per group, broadcast to heads
    cb = jnp.einsum("bcign,bcjgn->bcijg", Cq, Bq)    # [B,nc,Q,Q,G]
    cb = jnp.repeat(cb, HperG, axis=-1)              # [B,nc,Q,Q,H]
    M = cb * decay
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xdt)

    # ---- chunk states ----------------------------------------------------------
    # S_c = Σ_j exp(seg_total - cum_j) B_j ⊗ xdt_j   -> [B,nc,H,N,P]
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)          # [B,nc,Q,H]
    Bh = jnp.repeat(Bq, HperG, axis=3) if G != H else Bq            # [B,nc,Q,H,N]
    states = jnp.einsum("bcqhn,bcqhp,bcqh->bchnp", Bh, xdt, decay_to_end)

    # ---- inter-chunk recurrence (sequential scan over chunks) -----------------
    def body(S, inputs):
        state_c, seg_c = inputs                      # [B,H,N,P], [B,H]
        S_prev = S
        S = S * jnp.exp(seg_c)[:, :, None, None] + state_c
        return S, S_prev

    S0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((Bsz, H, N, P), f32))
    # scan over chunk axis: move nc first
    states_t = jnp.moveaxis(states, 1, 0)            # [nc,B,H,N,P]
    seg_t = jnp.moveaxis(seg_total, 1, 0)            # [nc,B,H]
    final_state, S_prevs = jax.lax.scan(body, S0, (states_t, seg_t))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)            # [B,nc,H,N,P]

    # ---- inter-chunk contribution ---------------------------------------------
    Ch = jnp.repeat(Cq, HperG, axis=3) if G != H else Cq            # [B,nc,Q,H,N]
    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Ch, S_prevs,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, Lp, H, P)
    if pad:
        y = y[:, :L]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state: jax.Array, x_t: jax.Array, dt_t: jax.Array,
                    A: jax.Array, B_t: jax.Array, C_t: jax.Array):
    """One-token recurrence.

    state: [B, H, N, P]; x_t: [B, H, P]; dt_t: [B, H];
    B_t/C_t: [B, G, N].  Returns (y [B, H, P], new_state).
    """
    Bsz, H, N, P = state.shape
    G = B_t.shape[1]
    HperG = H // G
    f32 = jnp.float32
    state = state.astype(f32)
    a = jnp.exp(dt_t.astype(f32) * A.astype(f32))           # [B, H]
    xdt = (x_t.astype(f32) * dt_t.astype(f32)[..., None])   # [B, H, P]
    Bh = jnp.repeat(B_t.astype(f32), HperG, axis=1)         # [B, H, N]
    Ch = jnp.repeat(C_t.astype(f32), HperG, axis=1)
    new_state = state * a[:, :, None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh, xdt)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------

def _project(params: dict, x: jax.Array):
    """x: [..., D] -> (z, xBC, dt) via the three separate projections."""
    return x @ params["w_z"], x @ params["w_xBC"], x @ params["w_dt"]


def _split_xBC(xBC: jax.Array, dm: dict):
    d_in, g, n = dm["d_inner"], dm["groups"], dm["state"]
    x = xBC[..., :d_in]
    B = xBC[..., d_in:d_in + g * n]
    C = xBC[..., d_in + g * n:]
    return x, B, C


def mamba2_apply(params: dict, x: jax.Array, cfg: ModelConfig, *,
                 impl: str = "chunked", return_state: bool = False):
    """Full-sequence Mamba2 block.  x: [B, L, D] -> [B, L, D].

    ``return_state=True`` also returns (ssm_state [B,H,N,P] f32,
    conv_state [B,W-1,conv_dim]) so serving prefill can seed decode."""
    dm = ssm_dims(cfg)
    Bsz, L, D = x.shape
    H, P, G, N = dm["nheads"], dm["head_dim"], dm["groups"], dm["state"]
    W = dm["conv_width"]

    z, xBC_raw, dt = _project(params, x)
    z = constrain(z, "batch", "seq", "hidden")
    xBC_raw = constrain(xBC_raw, "batch", "seq", "channels")
    xBC = jax.nn.silu(causal_conv(xBC_raw, params["conv_w"], params["conv_b"]))
    xs, Bm, Cm = _split_xBC(xBC, dm)
    xs = constrain(xs.reshape(Bsz, L, H, P), "batch", "seq", "heads", None)
    Bm = Bm.reshape(Bsz, L, G, N)
    Cm = Cm.reshape(Bsz, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    if impl == "pallas":
        from repro.kernels import ops as kops
        y, final_state = kops.ssd_scan(xs, dt, A, Bm, Cm, chunk=dm["chunk"])
    else:
        y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, chunk=dm["chunk"])
    y = y + xs * params["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(Bsz, L, dm["d_inner"])
    y = rmsnorm_apply(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"]
    if not return_state:
        return out
    # conv state = last W-1 RAW xBC inputs (pre-conv, pre-silu), left-padded
    tail = xBC_raw[:, -(W - 1):, :]
    if L < W - 1:
        tail = jnp.pad(xBC_raw, ((0, 0), (W - 1 - L, 0), (0, 0)))
    return out, (final_state, tail)


def mamba2_decode(params: dict, x: jax.Array, cfg: ModelConfig,
                  ssm_state: jax.Array, conv_state: jax.Array):
    """One-token decode.  x: [B, 1, D]; returns (y [B,1,D], ssm', conv')."""
    dm = ssm_dims(cfg)
    Bsz = x.shape[0]
    H, P, G, N = dm["nheads"], dm["head_dim"], dm["groups"], dm["state"]

    z, xBC, dt = _project(params, x[:, 0, :])
    xBC, conv_state = conv_step(xBC, conv_state, params["conv_w"],
                                params["conv_b"])
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = _split_xBC(xBC, dm)
    xs = xs.reshape(Bsz, H, P)
    Bm = Bm.reshape(Bsz, G, N)
    Cm = Cm.reshape(Bsz, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, ssm_state = ssd_decode_step(ssm_state, xs, dt, A, Bm, Cm)
    y = y + xs * params["D"][None, :, None].astype(y.dtype)
    y = y.reshape(Bsz, dm["d_inner"])
    y = rmsnorm_apply(params["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return (y @ params["out_proj"])[:, None, :], ssm_state, conv_state
