"""Model zoo: composable layers + family assemblies (see transformer.py)."""
from . import layers, mamba2, moe, transformer
from .transformer import decode_step, forward, init, init_cache

__all__ = ["layers", "mamba2", "moe", "transformer",
           "init", "forward", "init_cache", "decode_step"]
