"""Core transformer layers: RMSNorm, RoPE/M-RoPE, GQA/MQA attention, MLP.

Pure-functional: params are nested dicts of jnp arrays; every `init_*` has a
matching `*_apply`.  Attention has three implementations, selected by
RunConfig.attention_impl:

* ``naive``   — full score matrix (tests/smoke only; O(S²) memory)
* ``chunked`` — lax.scan online-softmax over KV chunks (flash-attention
                algorithm in pure JAX; bounded HLO temps — the dry-run path)
* ``pallas``  — the TPU kernel in repro.kernels (validated interpret=True)

Numerics: params in cfg.param_dtype (default bf16), attention logits and
softmax accumulation in f32, residual stream in activation dtype.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.act_sharding import constrain


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions [..., S] -> angles [..., S, head_dim//2] (f32)."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * inv_freq


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections: tuple) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions: [B, 3, S] — (temporal, height, width) position ids.  The
    head_dim//2 frequency slots are partitioned into `sections`; slots in
    section j take their position from stream j.  For pure text the three
    streams are identical and M-RoPE degrades to 1-D RoPE (paper 2409.12191).
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    chunks = []
    start = 0
    for j, width in enumerate(sections):
        pos_j = positions[:, j, :]                          # [B, S]
        chunks.append(pos_j.astype(jnp.float32)[..., None]
                      * inv_freq[start:start + width])      # [B, S, width]
        start += width
    return jnp.concatenate(chunks, axis=-1)                 # [B, S, half]


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: [B, S, H, Dh]; angles: [B, S, Dh//2] (broadcast over heads)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :]   # [B, S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

_NEG_INF = -2.0e30


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, q_offset: int = 0) -> jax.Array:
    """Reference attention.  q: [B,Sq,H,Dh], k/v: [B,Sk,KH,Dh] with H=KH*G."""
    B, Sq, H, Dh = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, Dh).astype(jnp.float32)
    scale = 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k.astype(jnp.float32)) * scale
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(k.shape[1])
        mask = kpos[None, :] > qpos[:, None]                # [Sq, Sk]
        s = jnp.where(mask[None, :, None, None, :], _NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int = 1024,
                      q_offset: int = 0) -> jax.Array:
    """Online-softmax attention over KV chunks (flash algorithm, pure JAX).

    Memory: O(Sq·H·Dh + Sq·H·chunk) instead of O(Sq·Sk·H).  This is the
    implementation the dry-run lowers — honest FLOPs, bounded temps.
    """
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    KH = k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(Dh)
    chunk = min(chunk, Sk)
    n_chunks = cdiv(Sk, chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = (q.reshape(B, Sq, KH, G, Dh) * scale).astype(q.dtype)
    qpos = q_offset + jnp.arange(Sq)

    def body(carry, idx):
        acc, m, l = carry
        ks = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, axis=1)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, ks,
                       preferred_element_type=jnp.float32)  # [B,Sq,KH,G,C]
        kpos = idx * chunk + jnp.arange(chunk)
        invalid = kpos[None, :] >= Sk                       # padding
        if causal:
            invalid = invalid | (kpos[None, :] > qpos[:, None])
        s = jnp.where(invalid[None, :, None, None, :], _NEG_INF, s)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(v.dtype), vs,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, KH, G, Dh), jnp.float32)
    m0 = jnp.full((B, Sq, KH, G), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KH, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dh).astype(q.dtype)


def decode_attention_chunked(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, cache_len,
                             chunk: int = 2048) -> jax.Array:
    """Flash-decoding in pure JAX: online softmax over cache chunks.

    Never materializes the [B, H, S] score tensor — the scan body touches
    one [B, H, chunk] tile at a time, so HBM traffic approaches the
    irreducible cache read (the jnp analogue of kernels/decode_attention).
    q: [B, H, Dh]; caches [B, S, KH, Dh]; cache_len scalar or [B(,1)].
    """
    B, H, Dh = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(Dh)
    qg = (q.reshape(B, KH, G, Dh) * scale)
    lens = jnp.asarray(cache_len).reshape(-1, 1)    # [B or 1, 1]
    chunk = min(chunk, S)
    n_chunks = cdiv(S, chunk)
    pad = n_chunks * chunk - S
    kp = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k_cache
    vp = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v_cache

    def body(carry, idx):
        acc, m, l = carry
        ks = jax.lax.dynamic_slice_in_dim(kp, idx * chunk, chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, idx * chunk, chunk, axis=1)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, ks,
                       preferred_element_type=jnp.float32)  # [B,KH,G,chunk]
        pos = idx * chunk + jnp.arange(chunk)
        valid = pos[None, :] < lens                          # [B or 1, chunk]
        s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgs,bskd->bkgd", p.astype(vs.dtype), vs,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, KH, G, Dh), jnp.float32)
    m0 = jnp.full((B, KH, G), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, H, Dh).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array | int) -> jax.Array:
    """Single-token attention against a KV cache.

    q: [B, H, Dh]; k_cache/v_cache: [B, S, KH, Dh]; cache_len: filled length.
    Scores stay [B, H, S] — small; softmax reduction over a (possibly
    model-axis-sharded) S is handled by GSPMD with an all-reduce.
    """
    B, H, Dh = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(Dh)
    qg = (q.reshape(B, KH, G, Dh) * scale)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)      # [B,KH,G,S]
    lens = jnp.asarray(cache_len)
    if lens.ndim == 0:
        valid = (jnp.arange(S) < lens)[None, :]             # [1, S]
    else:
        valid = jnp.arange(S)[None, :] < lens.reshape(-1, 1)  # [B, S]
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + norm + rope + core)
# ---------------------------------------------------------------------------

def init_attention(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, H, KH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(H * Dh)
    p = {
        "wq": (jax.random.normal(k1, (d, H * Dh)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, KH * Dh)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, KH * Dh)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H * Dh, d)) * so).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(Dh, dtype)
        p["k_norm"] = init_rmsnorm(Dh, dtype)
    return p


def attention_qkv(params: dict, x: jax.Array, cfg: ModelConfig,
                  angles: jax.Array):
    """Project + (qk-norm) + rope.  Returns q [B,S,H,Dh], k/v [B,S,KH,Dh]."""
    B, S, _ = x.shape
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, H, Dh)
    k = (x @ params["wk"]).reshape(B, S, KH, Dh)
    v = (x @ params["wv"]).reshape(B, S, KH, Dh)
    if cfg.qk_norm:
        q = rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    # attention operates on the full sequence per head shard: under
    # sequence parallelism the seq dim is gathered at this boundary
    # (Megatron-style), so q/k/v pin heads but leave seq unsharded
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def attention_apply(params: dict, x: jax.Array, cfg: ModelConfig, *,
                    angles: jax.Array | None, causal: bool = True,
                    impl: str = "chunked", chunk: int = 1024,
                    kv_override: tuple | None = None) -> jax.Array:
    """Full attention block on [B, S, D].  kv_override: cross-attention."""
    B, S, _ = x.shape
    H, Dh = cfg.n_heads, cfg.resolved_head_dim
    q, k, v = attention_qkv(params, x, cfg, angles)
    if kv_override is not None:
        k, v = kv_override
    if impl == "naive":
        o = naive_attention(q, k, v, causal=causal)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=causal)
    else:
        o = chunked_attention(q, k, v, causal=causal, chunk=chunk)
    o = constrain(o, "batch", None, "heads", None)
    return o.reshape(B, S, H * Dh) @ params["wo"]


def attention_decode_apply(params: dict, x: jax.Array, cfg: ModelConfig, *,
                           angles: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, cache_len) -> tuple:
    """One-token decode.  x: [B, 1, D].  Returns (out [B,1,D], new_k, new_v).

    The new token's K/V ([B,1,KH,Dh]) are returned for the caller to insert
    into the cache (cache layout/update policy lives in repro.serve.kvcache).
    """
    B, S, _ = x.shape
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = attention_qkv(params, x, cfg, angles)
    # attend over cache plus the new token's own K/V appended logically:
    # the engine writes k/v into the cache at position cache_len *before*
    # calling, so attending over [0, cache_len] covers it.
    o = decode_attention(q[:, 0], k_cache, v_cache, cache_len)
    out = o.reshape(B, 1, H * Dh) @ params["wo"]
    return out, k, v


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    if cfg.act == "silu":
        return {
            "w_gate": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
            "w_up": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
            "w_down": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
        }
    return {
        "w_up": (jax.random.normal(k1, (d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k2, (f, d)) * s_out).astype(dtype),
    }


def mlp_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.act == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    h = constrain(h, "batch", "seq", "hidden")
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"table": (jax.random.normal(k1, (cfg.vocab, cfg.d_model))
                   * 0.02).astype(dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab))
                        * 0.02).astype(dtype)
    return p


def embed_apply(params: dict, tokens: jax.Array, dtype,
                onehot: bool = False) -> jax.Array:
    """Token embedding lookup.

    ``onehot=True`` uses a one-hot matmul instead of a gather — required when
    the table is VOCAB-sharded (tied-embedding archs): XLA SPMD handles a
    sharded-contraction einsum cleanly, while a gather over a sharded vocab
    triggers involuntary full rematerialization (replicates the table).
    Untied archs shard the table on D, where the gather is communication-free.
    """
    table = params["table"].astype(dtype)
    if onehot:
        oh = jax.nn.one_hot(tokens, table.shape[0], dtype=dtype)
        return jnp.einsum("bsv,vd->bsd", oh, table)
    return table[tokens]


def unembed_apply(params: dict, x: jax.Array) -> jax.Array:
    """Logits in f32 (loss numerics)."""
    if "unembed" in params:
        w = params["unembed"]
    else:
        w = params["table"].T
    logits = jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    return constrain(logits, "batch", "seq", "vocab")
