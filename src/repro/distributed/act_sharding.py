"""Activation sharding constraints — logical-axis pins inside model code.

GSPMD propagation from parameter/input shardings alone is not enough at this
scale: observed failure on the (16,16) mesh was attention score tensors with
the *batch dim replicated* (propagation preferred head sharding and dropped
the data axis), inflating per-chip temps ~16×.  Production frameworks
(MaxText, EasyLM) pin activations explicitly; we do the same with logical
names resolved against the active mesh.

Model code calls ``constrain(x, 'batch', 'seq', 'heads', None)`` — a no-op
outside a jit built by repro.train.steps (tests/smoke run unconstrained on
one device).  The jit builders install the context:

    with activation_mesh(mesh, run):
        ... trace ...

Logical axes:
  batch    -> as many DP axes ('pod','data') as divide the dim
  seq      -> run.seq_axis (None by default; 'model' enables sequence/context
              parallelism for long-context cells)
  heads / kv_heads / hidden / channels / vocab -> 'model' when divisible
  expert   -> run.expert_axis (None = experts replicated / TP-sharded inside)
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()


def _axis_size(mesh: Mesh, name) -> int:
    n = 1
    for a in (name if isinstance(name, tuple) else (name,)):
        if a is not None and a in mesh.shape:
            n *= mesh.shape[a]
    return n


@contextlib.contextmanager
def activation_mesh(mesh: Mesh, *, seq_axis=None, expert_axis=None):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = {"mesh": mesh, "seq_axis": seq_axis, "expert_axis": expert_axis}
    try:
        yield
    finally:
        _TLS.ctx = prev


def current_mesh() -> Mesh | None:
    ctx = getattr(_TLS, "ctx", None)
    return ctx["mesh"] if ctx else None


def _resolve(logical, dim: int, ctx) -> object:
    mesh = ctx["mesh"]
    if logical is None:
        return None
    if logical == "batch":
        axes = []
        prod = 1
        pool = ("pod", "data") if "pod" in mesh.shape else ("data",)
        for a in pool:
            if dim % (prod * mesh.shape[a]) == 0:
                axes.append(a)
                prod *= mesh.shape[a]
        return tuple(axes) if axes else None
    if logical == "seq":
        a = ctx["seq_axis"]
        return a if (a and dim % _axis_size(mesh, a) == 0) else None
    if logical == "expert":
        a = ctx["expert_axis"]
        return a if (a and dim % _axis_size(mesh, a) == 0) else None
    if logical in ("heads", "kv_heads", "hidden", "channels", "vocab",
                   "model"):
        return "model" if dim % mesh.shape.get("model", 1) == 0 else None
    raise ValueError(f"unknown logical axis {logical!r}")


def constrain(x: jax.Array, *logical) -> jax.Array:
    """Pin x's sharding by logical dim names; no-op without a context.

    If two dims resolve to the same mesh axis (e.g. seq-parallel 'seq' and
    'heads' both wanting 'model'), the FIRST keeps it — a PartitionSpec may
    not repeat an axis."""
    ctx = getattr(_TLS, "ctx", None)
    if ctx is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    entries = []
    used: set = set()
    for l, d in zip(logical, x.shape):
        e = _resolve(l, d, ctx)
        flat = e if isinstance(e, tuple) else (e,) if e else ()
        if any(a in used for a in flat):
            e = None
        used.update(flat)
        entries.append(e)
    spec = P(*entries)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx["mesh"], spec))
