"""Distribution layer: sharding rules, ZeRO, compressed collectives."""
from . import sharding

__all__ = ["sharding"]
