"""Sharding rules: parameter/batch/cache PartitionSpecs per architecture.

This is the platform's "automated data communication" at the device level
(DESIGN.md §2): the operator derives every pjit sharding from the stream
schemas + mesh — users never write a PartitionSpec.

Axis meanings (launch.mesh):
  pod    — data parallelism across pods (hierarchical gradient reduction)
  data   — within-pod data parallelism; also the FSDP/ZeRO axis when
           run.zero3 is set (params/optimizer sharded over it)
  model  — tensor parallelism (heads / FFN hidden / experts' hidden / SSM
           inner channels / vocab)

Rules are path-based over the param pytree; trailing-dim specs are defined
per weight kind and left-padded with None for stacked-layer leading dims.
Divisibility is checked: a dim is only sharded when the axis size divides it
(e.g. whisper's 20 heads are NOT sharded 16-way — its attention runs
data-parallel while its MLP is tensor-parallel; recorded per-arch).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _div(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


def batch_axes(mesh: Mesh) -> tuple:
    """The data-parallel axes: ('pod', 'data') when pod exists."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_spec_for(mesh: Mesh, global_batch: int, extra_dims: int) -> P:
    """Shard the leading batch dim over as many DP axes as divide it."""
    axes = []
    prod = 1
    for a in batch_axes(mesh):
        if _div(global_batch, prod * axis_size(mesh, a)):
            axes.append(a)
            prod *= axis_size(mesh, a)
    lead = tuple(axes) if axes else None
    return P(lead, *([None] * extra_dims))


def burst_spec(mesh: Mesh, batch: int, field_shape: tuple | None,
               hint: Any = None) -> P:
    """PartitionSpec for ONE burst-stacked stream field.

    This is how a fused segment's batched program lands on the mesh: the
    leading (burst) dim splits over the DP axes that divide ``batch`` —
    exactly :func:`batch_spec_for`'s rule — and the trailing per-message
    dims follow the field's declared sharding ``hint``
    (:class:`repro.core.schema.ShardSpec` or any axes iterable) wherever
    the named axis exists in the mesh, divides the dim, and isn't already
    spent on the batch.  Axes the mesh doesn't have (a ``'model'`` hint on
    a data-only mesh) replicate silently — the hint is a capability
    declaration, not a demand.
    """
    lead_axes = []
    prod = 1
    for a in batch_axes(mesh):
        if _div(batch, prod * axis_size(mesh, a)):
            lead_axes.append(a)
            prod *= axis_size(mesh, a)
    lead = tuple(lead_axes) if lead_axes else None
    used = set(lead_axes)
    shape = tuple(field_shape) if field_shape is not None else ()
    axes = tuple(hint) if hint is not None else ()
    trailing = []
    for i, dim in enumerate(shape):
        ax = axes[i] if i < len(axes) else None
        if (ax is not None and ax in mesh.shape and ax not in used
                and isinstance(dim, int) and _div(dim, axis_size(mesh, ax))):
            trailing.append(ax)
            used.add(ax)
        else:
            trailing.append(None)
    return P(lead, *trailing)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

def _param_rule(path: tuple[str, ...], shape: tuple[int, ...],
                cfg: ModelConfig, run: RunConfig, mesh: Mesh) -> P:
    """Trailing-dims PartitionSpec for one weight; leading stack dims padded."""
    tp = axis_size(mesh, "model")
    dp = axis_size(mesh, "data")
    name = path[-1]
    ctx = path[-2] if len(path) >= 2 else ""
    H, KH = cfg.n_heads, cfg.n_kv_heads

    # fsdp axis on a given dim only if divisible
    def fs(dim: int):
        return "data" if (run.zero3 and _div(dim, dp)) else None

    def mp(dim: int, ok: bool = True):
        return "model" if (ok and _div(dim, tp)) else None

    heads_shardable = _div(H, tp)          # q/o projections
    kv_shardable = _div(KH, tp)            # k/v projections (GQA: often not)

    spec: tuple
    if name == "table":                    # embedding [V, D]
        if cfg.tie_embeddings:
            # vocab-sharded (logits stay sharded for the xent); lookup is a
            # one-hot einsum so the sharded-V contraction partitions cleanly
            spec = (mp(shape[-2]), None)
        else:
            # D-sharded: the token gather is then communication-free
            spec = (None, mp(shape[-1]))
    elif name == "unembed":                # [D, V]
        spec = (fs(shape[-2]), mp(shape[-1]))
    elif name == "enc_pos":                # [F, D]
        spec = (None, None)
    elif name == "scale":                  # norm scales; shard only SSM gate
        if ctx == "gate_norm":
            spec = (mp(shape[-1]),)
        else:
            spec = (None,)
    elif name == "wq":                     # [D, H*Dh]
        spec = (fs(shape[-2]), mp(shape[-1], heads_shardable))
    elif name in ("wk", "wv"):             # [D, KH*Dh]
        spec = (fs(shape[-2]), mp(shape[-1], kv_shardable))
    elif name == "wo":                     # [H*Dh, D]
        spec = (mp(shape[-2], heads_shardable), fs(shape[-1]))
    elif name in ("w_gate", "w_up"):
        if len(shape) >= 3 and shape[-3] == getattr(cfg.moe, "num_experts", -1):
            # MoE experts [E, D, F]: expert-TP on F + FSDP on D
            spec = (None, fs(shape[-2]), mp(shape[-1]))
        else:                              # [D, F]
            spec = (fs(shape[-2]), mp(shape[-1]))
    elif name == "w_down":
        if len(shape) >= 3 and shape[-3] == getattr(cfg.moe, "num_experts", -1):
            spec = (None, mp(shape[-2]), fs(shape[-1]))
        else:                              # [F, D]
            spec = (mp(shape[-2]), fs(shape[-1]))
    elif name == "router":                 # [D, E]
        spec = (None, None)
    elif name in ("w_z", "w_xBC"):         # [D, d_in] / [D, conv_dim]
        spec = (fs(shape[-2]), mp(shape[-1]))
    elif name == "w_dt":                   # [D, nheads]
        spec = (fs(shape[-2]), mp(shape[-1]))
    elif name == "conv_w":                 # [W, conv_dim]
        spec = (None, mp(shape[-1]))
    elif name == "conv_b":                 # [conv_dim]
        spec = (mp(shape[-1]),)
    elif name in ("A_log", "D", "dt_bias"):  # [nheads]
        spec = (mp(shape[-1]),)
    elif name == "out_proj":               # [d_in, D]
        spec = (mp(shape[-2]), fs(shape[-1]))
    else:
        spec = tuple(None for _ in shape)

    pad = len(shape) - len(spec)
    assert pad >= 0, (path, shape, spec)
    return P(*([None] * pad + list(spec)))


def _path_names(path) -> tuple[str, ...]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
        else:
            out.append(str(p))
    return tuple(out)


def param_specs(params_shape: Any, cfg: ModelConfig, run: RunConfig,
                mesh: Mesh) -> Any:
    """Pytree of PartitionSpec matching a params (shape) pytree."""
    def rule(path, leaf):
        return _param_rule(_path_names(path), tuple(leaf.shape), cfg, run, mesh)
    return jax.tree_util.tree_map_with_path(rule, params_shape)


def param_shardings(params_shape: Any, cfg: ModelConfig, run: RunConfig,
                    mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, cfg, run, mesh))


# ---------------------------------------------------------------------------
# Optimizer-state rules (ZeRO-1)
# ---------------------------------------------------------------------------

def opt_state_spec_from_param(spec: P, shape: tuple[int, ...],
                              run: RunConfig, mesh: Mesh) -> P:
    """Adam m/v: same layout as the param, plus ZeRO-1 sharding of the first
    unsharded divisible dim over 'data' (when the param isn't already
    data-sharded via zero3)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    flat = []
    for e in entries:
        flat.extend(e if isinstance(e, tuple) else [e])
    if "data" in flat:
        return P(*entries)
    dp = axis_size(mesh, "data")
    for i, e in enumerate(entries):
        if e is None and shape[i] % dp == 0 and shape[i] >= dp:
            entries[i] = "data"
            break
    return P(*entries)


# ---------------------------------------------------------------------------
# Cache / batch rules
# ---------------------------------------------------------------------------

def cache_specs(cache_shape: Any, cfg: ModelConfig, run: RunConfig,
                mesh: Mesh, batch: int) -> Any:
    """Decode-state shardings.

    KV caches [L, B, S, KH, Dh]: batch->data when divisible; seq->model when
    run.seq_shard_kv (flash-decoding-style sharded cache reads; softmax
    reductions over the sharded seq become all-reduces).  SSM states
    [L, B, H, N, P]: batch->data, heads->model.  Conv states: channel->model.
    """
    dp = axis_size(mesh, "data")
    tp = axis_size(mesh, "model")
    b_axis = "data" if _div(batch, dp) else None

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shape = tuple(leaf.shape)
        if name in ("k", "v", "xk", "xv"):
            s_axis = "model" if (run.seq_shard_kv and _div(shape[2], tp)) else None
            kh_axis = None
            if s_axis is None and _div(shape[3], tp):
                kh_axis = "model"
            return P(None, b_axis, s_axis, kh_axis, None)
        if name == "ssm":                   # [L, B, H, N, P]
            h_axis = "model" if _div(shape[2], tp) else None
            return P(None, b_axis, h_axis, None, None)
        if name == "conv":                  # [L, B, W-1, conv_dim]
            c_axis = "model" if _div(shape[3], tp) else None
            return P(None, b_axis, None, c_axis)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def batch_specs(batch_shape: Any, mesh: Mesh) -> Any:
    """Token batches: leading dim over DP axes; scalars replicated."""
    def rule(path, leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        return batch_spec_for(mesh, shape[0], len(shape) - 1)
    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda s: isinstance(s, P))


def sharding_report(params_shape: Any, specs: Any, mesh: Mesh) -> dict:
    """Bytes-per-device accounting (pre-compile sanity check)."""
    total = 0
    per_dev = 0
    for leaf, spec in zip(jax.tree.leaves(params_shape),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda s: isinstance(s, P))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        bytes_ = n * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
        shards = 1
        for e in spec:
            for a in (e if isinstance(e, tuple) else [e] if e else []):
                shards *= axis_size(mesh, a)
        total += bytes_
        per_dev += bytes_ / max(shards, 1)
    return {"total_bytes": int(total), "bytes_per_device": int(per_dev)}
