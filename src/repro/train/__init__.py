"""Training substrate: optimizer, steps, trainer, checkpoint, fault tolerance."""
from . import optimizer, steps

__all__ = ["optimizer", "steps"]
