"""Fault tolerance: preemption, step-time stragglers, elastic rescale.

Three mechanisms (DESIGN.md §7), each independently testable:

* :class:`PreemptionHandler` — SIGTERM/flag -> the trainer finishes the
  current step, writes a blocking checkpoint, and exits cleanly (how TPU
  preemption notices are handled in practice).
* :class:`StepTimeMonitor` — EWMA + deviation of device-step wall time;
  flags straggler steps (slow host / failing HBM / thermal throttle).  On a
  real pod this feeds the controller that evicts the slow host; here it
  feeds operator events.  (Host-AU stragglers are handled separately by the
  DataX operator's reconcile loop.)
* :class:`ElasticController` — on membership change: rebuild the mesh from
  the surviving device set, re-derive shardings, restore the latest
  checkpoint onto the new mesh (CheckpointManager.restore handles the
  re-lay-out).  Demonstrated in tests by shrinking an 8-device host mesh
  to 4 devices mid-run with identical loss trajectories.
"""
from __future__ import annotations

import signal
import threading
import time

import jax

from repro.distributed import sharding as shard


class PreemptionHandler:
    def __init__(self, install_signal: bool = False):
        self._flag = threading.Event()
        if install_signal:  # real deployments; tests trigger .preempt()
            signal.signal(signal.SIGTERM, lambda *_: self._flag.set())

    def preempt(self) -> None:
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()


class StepTimeMonitor:
    """Flags steps slower than `factor` × EWMA as stragglers."""

    def __init__(self, factor: float = 2.5, alpha: float = 0.2,
                 warmup_steps: int = 3):
        self.factor = factor
        self.alpha = alpha
        self.warmup_steps = warmup_steps
        self.ewma: float | None = None
        self.seen = 0
        self.straggler_steps: list[tuple[int, float, float]] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step was a straggler."""
        self.seen += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (self.seen > self.warmup_steps
                        and dt > self.factor * self.ewma)
        if is_straggler:
            self.straggler_steps.append((step, dt, self.ewma))
        else:  # stragglers don't poison the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class ElasticController:
    """Rebuilds (mesh, shardings) for the surviving device set."""

    def __init__(self, cfg, run):
        self.cfg = cfg
        self.run = run
        self.events: list[str] = []

    def build_mesh(self, devices=None, model_axis: int = 1):
        devices = devices if devices is not None else jax.devices()
        n = len(devices)
        if n % model_axis:
            raise ValueError(f"{n} devices not divisible by model={model_axis}")
        import numpy as np
        arr = np.asarray(devices).reshape(n // model_axis, model_axis)
        from jax.sharding import Mesh
        mesh = Mesh(arr, ("data", "model"))
        self.events.append(f"mesh rebuilt: data={n//model_axis} "
                           f"model={model_axis} ({n} devices)")
        return mesh

    def reshard_plan(self, params_shape, mesh):
        """New-mesh shardings for params (restore target)."""
        specs = shard.param_specs(params_shape, self.cfg, self.run, mesh)
        return shard.to_shardings(specs, mesh)

    def on_membership_change(self, surviving_devices, ckpt_manager,
                             state_like, model_axis: int = 1):
        """The full elastic path: new mesh -> new shardings -> restore."""
        mesh = self.build_mesh(surviving_devices, model_axis)
        self.reshard_plan(
            jax.eval_shape(lambda s: s["params"], state_like)
            if isinstance(state_like, dict) and "params" in state_like
            else state_like, mesh)
        t0 = time.monotonic()
        state, manifest = ckpt_manager.restore(state_like)
        self.events.append(
            f"restored step {manifest['step']} onto new mesh in "
            f"{time.monotonic()-t0:.2f}s")
        return mesh, state, manifest
