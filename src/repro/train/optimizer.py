"""AdamW + LR schedule + global-norm clipping + gradient compression.

Hand-rolled (no optax dependency) so optimizer-state sharding (ZeRO-1) and
compression hooks stay explicit:

* m/v in f32 regardless of param dtype; optional f32 master weights.
* warmup + cosine schedule.
* gradient compression with error feedback (bf16 cast or int8 EF-SGD-style):
  the distributed-optimization trick — on a real pod the quantized tensor is
  what crosses the wire; here the quantize->dequantize runs inside the step
  so convergence behaviour is faithfully reproduced, and the collectives
  benchmark (benchmarks/bench_compression.py) demonstrates the wire-bytes
  effect via shard_map.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------

def lr_schedule(step: jax.Array, run: RunConfig,
                total_steps: int = 100_000) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(run.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - run.warmup_steps)
                    / max(total_steps - run.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return run.learning_rate * warm * (0.1 + 0.9 * cos)


# ---------------------------------------------------------------------------
# Compression (error feedback)
# ---------------------------------------------------------------------------

def compress_grad(g: jax.Array, err: jax.Array | None, mode: str):
    """Returns (decompressed grad, new error buffer)."""
    if mode == "none" or g.dtype == jnp.int32:
        return g, err
    if mode == "bf16":
        gq = g.astype(jnp.bfloat16).astype(jnp.float32)
        return gq, err
    if mode == "int8_ef":
        g32 = g.astype(jnp.float32) + (err if err is not None else 0.0)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq, (g32 - deq)
    raise ValueError(f"unknown compression mode {mode!r}")


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def init_opt_state(params: Any, run: RunConfig,
                   master_weights: bool = False) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if run.grad_compression == "int8_ef":
        state["err"] = jax.tree.map(zeros32, params)
    if master_weights:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads: Any, params: Any, state: dict, run: RunConfig,
                 total_steps: int = 100_000):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = lr_schedule(count, run, total_steps)

    # compression with error feedback
    if run.grad_compression != "none":
        errs = state.get("err")
        if errs is not None:
            gq = jax.tree.map(
                lambda g, e: compress_grad(g, e, run.grad_compression),
                grads, errs)
            grads = jax.tree.map(lambda t: t[0], gq,
                                 is_leaf=lambda t: isinstance(t, tuple))
            new_err = jax.tree.map(lambda t: t[1], gq,
                                   is_leaf=lambda t: isinstance(t, tuple))
        else:
            grads = jax.tree.map(
                lambda g: compress_grad(g, None, run.grad_compression)[0],
                grads)
            new_err = None
    else:
        new_err = state.get("err")

    # global-norm clip (f32)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, run.grad_clip / jnp.maximum(gnorm, 1e-12))
    b1, b2, eps = run.beta1, run.beta2, 1e-8
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    masters = state.get("master")

    def upd(p, g, m, v, master=None):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        base = (master if master is not None else p.astype(jnp.float32))
        step = lr * (mhat / (jnp.sqrt(vhat) + eps)
                     + run.weight_decay * base)
        new_master = base - step
        return new_master.astype(p.dtype), m, v, new_master

    if masters is not None:
        out = jax.tree.map(upd, params, grads, state["m"], state["v"], masters)
    else:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v),
                           params, grads, state["m"], state["v"])

    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    if masters is not None:
        new_state["master"] = jax.tree.map(
            lambda t: t[3], out, is_leaf=lambda t: isinstance(t, tuple))
    if new_err is not None:
        new_state["err"] = new_err
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
