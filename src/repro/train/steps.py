"""jit-able train / prefill / decode steps + their sharding assignments.

These are the *device AUs* of the DataX platform (DESIGN.md §3): the operator
registers them as analytics units whose stream edges lower to pjit shardings
instead of bus hops.  ``make_*_step`` builds the pure function; ``*_shardings``
derives every in/out sharding from the config + mesh — the paper's "automated
data communication" applied to the TPU collective layer.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import ModelConfig, RunConfig
from repro.distributed import sharding as shard
from repro.distributed.act_sharding import activation_mesh

from . import optimizer as opt


def _with_act_mesh(fn, mesh: Mesh, run: RunConfig):
    """Wrap a step so tracing happens under the activation-sharding context
    (model-internal `constrain()` calls resolve against this mesh)."""
    @functools.wraps(fn)
    def wrapped(*args):
        with activation_mesh(
                mesh,
                seq_axis="model" if run.seq_parallel else None,
                expert_axis=run.expert_axis):
            return fn(*args)
    return wrapped


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits f32 [B, S, V]; labels i32 [B, S] (-1 = masked).

    The label pick is a one-hot contraction, NOT take_along_axis: a gather
    over the vocab-sharded logits would trigger involuntary replication of
    the full [B, S, V] tensor; the einsum partitions cleanly (the sharded-V
    contraction becomes a small all-reduce of [B, S])."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels.clip(0), logits.shape[-1],
                            dtype=logits.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((lse - ll) * mask) / jnp.maximum(mask.sum(), 1.0)


def make_loss_fn(cfg: ModelConfig, run: RunConfig):
    def loss_fn(params, batch):
        logits, aux = models.forward(params, batch, cfg, run)
        xent = softmax_xent(logits, batch["labels"])
        loss = xent
        for k in ("moe_load_balance", "moe_z_loss"):
            if k in aux:
                loss = loss + aux[k]
        metrics = {"loss": loss, "xent": xent, **aux}
        return loss, metrics
    return loss_fn


# ---------------------------------------------------------------------------
# Train step (with microbatched gradient accumulation)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, run: RunConfig,
                    total_steps: int = 100_000, mesh: Mesh | None = None):
    loss_fn = make_loss_fn(cfg, run)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _constrain_mb(a):
        """Re-pin each microbatch leaf's batch dim to the DP axes: the
        [B]->[k, B/k] reshape otherwise leaves GSPMD free to scatter the
        sharding across both dims (observed: involuntary replication)."""
        if mesh is None:
            return a
        spec = shard.batch_spec_for(mesh, a.shape[1], a.ndim - 2)
        full = P(None, *spec)
        return jax.lax.with_sharding_constraint(a, NamedSharding(mesh, full))

    def train_step(params, opt_state, batch):
        k = run.microbatches
        if k > 1:
            # reshape [B, ...] -> [k, B/k, ...] and scan (grad accumulation)
            mb = jax.tree.map(
                lambda a: _constrain_mb(
                    a.reshape((k, a.shape[0] // k) + a.shape[1:])), batch)

            acc_dt = jnp.dtype(run.grad_accum_dtype)

            def acc(carry, mbatch):
                g_acc, loss_acc = carry
                (loss, metrics), grads = grad_fn(params, mbatch)
                # bf16 accumulation keeps the per-microbatch gradient
                # all-reduce in bf16 (half the wire bytes); f32 is exact
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dt), g_acc, grads)
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (g_sum, loss_sum), metrics = jax.lax.scan(acc, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / k, g_sum)
            metrics = jax.tree.map(lambda a: a.mean(), metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        params, opt_state, om = opt.adamw_update(grads, params, opt_state,
                                                 run, total_steps)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, run: RunConfig):
    """Inference prefill: forward pass producing next-token logits for the
    last position only (serving never materializes [B, S, V]); the engine
    variant also captures the KV cache (repro.serve.engine)."""
    def prefill_step(params, batch):
        logits, _ = models.forward(params, batch, cfg, run, last_only=True)
        return logits[:, -1]
    return prefill_step


def make_decode_step(cfg: ModelConfig, run: RunConfig):
    def serve_step(params, cache, batch):
        logits, cache = models.decode_step(params, cache, batch, cfg, run)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), logits, cache
    return serve_step


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(models.init, cfg=cfg), jax.random.key(0))


def abstract_opt_state(params_shape, run: RunConfig):
    return jax.eval_shape(
        functools.partial(opt.init_opt_state, run=run), params_shape)


def train_shardings(cfg: ModelConfig, run: RunConfig, mesh: Mesh):
    """Returns (params_shape, opt_shape, in_shardings, out_shardings)."""
    params_shape = abstract_params(cfg)
    pspecs = shard.param_specs(params_shape, cfg, run, mesh)
    opt_shape = abstract_opt_state(params_shape, run)

    def opt_spec(path, leaf):
        names = shard._path_names(path)
        if names[0] in ("m", "v", "err", "master"):
            sub = names[1:]
            pspec = _lookup(pspecs, sub)
            return shard.opt_state_spec_from_param(pspec, tuple(leaf.shape),
                                                   run, mesh)
        return P()

    ospecs = jax.tree_util.tree_map_with_path(opt_spec, opt_shape)
    return params_shape, opt_shape, pspecs, ospecs


def _lookup(tree, names):
    node = tree
    for n in names:
        if isinstance(node, dict):
            node = node[n]
        else:
            node = getattr(node, n)
    return node


def jit_train_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                   batch_shape: Any, total_steps: int = 100_000):
    """Fully-sharded jit of the train step; returns (fn, arg structs)."""
    params_shape, opt_shape, pspecs, ospecs = train_shardings(cfg, run, mesh)
    bspecs = shard.batch_specs(batch_shape, mesh)
    fn = jax.jit(
        _with_act_mesh(make_train_step(cfg, run, total_steps, mesh=mesh),
                       mesh, run),
        in_shardings=(shard.to_shardings(pspecs, mesh),
                      shard.to_shardings(ospecs, mesh),
                      shard.to_shardings(bspecs, mesh)),
        out_shardings=(shard.to_shardings(pspecs, mesh),
                       shard.to_shardings(ospecs, mesh),
                       NamedSharding(mesh, P())),
        donate_argnums=(0, 1),
    )
    return fn, (params_shape, opt_shape)


def jit_prefill_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                     batch_shape: Any):
    params_shape = abstract_params(cfg)
    pspecs = shard.param_specs(params_shape, cfg, run, mesh)
    bspecs = shard.batch_specs(batch_shape, mesh)
    # last-token logits [B, V]: batch follows the token batch, vocab on model
    first = jax.tree.leaves(batch_shape)[0]
    bspec = shard.batch_spec_for(mesh, first.shape[0], 0)
    logits_spec = P(bspec[0],
                    "model" if cfg.vocab % mesh.shape["model"] == 0 else None)
    fn = jax.jit(
        _with_act_mesh(make_prefill_step(cfg, run), mesh, run),
        in_shardings=(shard.to_shardings(pspecs, mesh),
                      shard.to_shardings(bspecs, mesh)),
        out_shardings=NamedSharding(mesh, logits_spec),
    )
    return fn, params_shape


def jit_decode_step(cfg: ModelConfig, run: RunConfig, mesh: Mesh,
                    batch: int, max_seq: int, batch_shape: Any):
    params_shape = abstract_params(cfg)
    pspecs = shard.param_specs(params_shape, cfg, run, mesh)
    cache_shape = jax.eval_shape(
        functools.partial(models.init_cache, cfg, batch, max_seq))
    cspecs = shard.cache_specs(cache_shape, cfg, run, mesh, batch)
    bspecs = shard.batch_specs(batch_shape, mesh)
    b_axis = shard.batch_spec_for(mesh, batch, 0)
    tok_spec = P(b_axis[0]) if batch > 1 else P(None)
    vocab_ok = cfg.vocab % mesh.shape["model"] == 0
    fn = jax.jit(
        _with_act_mesh(make_decode_step(cfg, run), mesh, run),
        in_shardings=(shard.to_shardings(pspecs, mesh),
                      shard.to_shardings(cspecs, mesh),
                      shard.to_shardings(bspecs, mesh)),
        out_shardings=(NamedSharding(mesh, tok_spec),
                       NamedSharding(mesh, P(tok_spec[0] if batch > 1 else None,
                                             "model" if vocab_ok else None)),
                       shard.to_shardings(cspecs, mesh)),
        donate_argnums=(1,),
    )
    return fn, (params_shape, cache_shape)
