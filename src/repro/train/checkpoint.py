"""Sharded, async, atomic checkpointing with elastic restore.

Design (1000-node story, DESIGN.md §7):

* **Sharded**: each host writes one compressed msgpack shard (zstd when
  available, zlib fallback — see ``core/compression.py``) containing
  only the param/optimizer slices it owns (`PartitionSpec`-addressable), so
  checkpoint bandwidth scales with hosts.  In this single-host container the
  shard set has one member, but the layout/manifest format is multi-shard.
* **Async**: `save()` snapshots device arrays to host memory synchronously
  (cheap) and writes to disk on a background thread — training continues.
* **Atomic**: shards land in `step_<N>.tmp/`; the manifest (with per-shard
  checksums) is written last and the directory os.replace()'d — a crash
  mid-write can never yield a "latest" pointer to a torn checkpoint.
* **Elastic restore**: restore() re-shards to whatever mesh the new job
  built (arrays are saved unsharded-addressable per leaf; jax.device_put
  with the new NamedSharding re-lays them out) — mesh shape may differ from
  the writer's (node loss / rescale).

The checkpoint registry (latest pointer, retention) lives in a DataX
StateStore database — the paper's platform-managed state, reused by the
platform itself.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core.bus import _default, _ext_hook
from repro.core.compression import codec_name, compress, decompress


class CheckpointError(RuntimeError):
    pass


def _tree_flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append("/".join(str(getattr(p, "key", getattr(p, "name", p)))
                              for p in path))
        leaves.append(leaf)
    return names, leaves, treedef


class CheckpointManager:
    """Save/restore train state under a root directory."""

    def __init__(self, root: str, *, keep: int = 3, host_id: int = 0,
                 n_hosts: int = 1):
        self.root = root
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        os.makedirs(root, exist_ok=True)
        self._writer: threading.Thread | None = None
        self._last_error: Exception | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, *, blocking: bool = False,
             meta: dict | None = None) -> None:
        """Snapshot to host, then write asynchronously (unless blocking)."""
        self.wait()  # one outstanding write at a time (double buffering)
        names, leaves, _ = _tree_flatten_with_names(state)
        host_leaves = [np.asarray(l) for l in leaves]   # device -> host copy

        def write():
            try:
                self._write(step, names, host_leaves, meta or {})
            except Exception as e:  # surfaced on next wait()/save()
                self._last_error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._writer = threading.Thread(target=write, daemon=True,
                                            name=f"ckpt-write-{step}")
            self._writer.start()

    def _write(self, step: int, names, host_leaves, meta: dict) -> None:
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)

        # this host's shard: every leaf it owns (single-host: all leaves)
        shard = {}
        for name, arr in zip(names, host_leaves):
            shard[name] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                           "data": arr.tobytes()}
        blob = compress(
            msgpack.packb(shard, default=_default, use_bin_type=True), level=1)
        shard_name = f"shard_{self.host_id:05d}.dxckpt"
        with open(os.path.join(tmp, shard_name), "wb") as f:
            f.write(blob)
        digest = hashlib.sha256(blob).hexdigest()

        manifest = {
            "step": step,
            "time": time.time(),
            "n_hosts": self.n_hosts,
            "leaves": names,
            "codec": codec_name(),
            "shards": {shard_name: {"sha256": digest, "bytes": len(blob)}},
            "meta": meta,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        self._update_latest(step)
        self._gc()

    def _update_latest(self, step: int) -> None:
        tmp = os.path.join(self.root, "latest.tmp")
        with open(tmp, "w") as f:
            json.dump({"step": step}, f)
        os.replace(tmp, os.path.join(self.root, "latest"))

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise CheckpointError(f"async checkpoint write failed: {err}")

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.root, d, "manifest.json")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        path = os.path.join(self.root, "latest")
        if os.path.exists(path):
            with open(path) as f:
                step = json.load(f)["step"]
            if step in self.all_steps():
                return step
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: Any, *, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``state_like``.

        ``shardings``: optional matching pytree of NamedSharding for the NEW
        mesh — this is the elastic path: the saved arrays are re-laid-out
        onto whatever mesh the restarted job constructed.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise CheckpointError(f"no checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        merged: dict[str, np.ndarray] = {}
        for shard_name, info in manifest["shards"].items():
            with open(os.path.join(d, shard_name), "rb") as f:
                blob = f.read()
            if hashlib.sha256(blob).hexdigest() != info["sha256"]:
                raise CheckpointError(f"checksum mismatch in {shard_name}")
            shard = msgpack.unpackb(
                decompress(blob),
                ext_hook=_ext_hook, raw=False, strict_map_key=False)
            for name, rec in shard.items():
                merged[name] = np.frombuffer(
                    rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])

        names, leaves, treedef = _tree_flatten_with_names(state_like)
        missing = [n for n in names if n not in merged]
        if missing:
            raise CheckpointError(f"checkpoint missing leaves: {missing[:5]}")
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(names))
        restored = []
        for name, like, sh in zip(names, leaves, shard_leaves):
            arr = merged[name]
            want = jnp.dtype(like.dtype)
            if str(want) != arr.dtype.name:
                arr = arr.astype(want)
            if sh is not None:
                restored.append(jax.device_put(arr, sh))
            else:
                restored.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, restored), manifest
