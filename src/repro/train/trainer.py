"""Trainer — the training loop as a DataX application.

The training run is literally a stream application on the platform
(DESIGN.md §3):

  corpus (sensor) -> packer (AU) -> batcher (AU) ->
      train_step (DEVICE AU, pjit on the mesh) -> {metrics stream,
      checkpoint actuator}

The Operator owns every host stage (restarts crashes, autoscales the packer,
replaces stragglers); the Trainer drives the device AU: pulls batch messages,
device_puts them against the derived shardings, steps, publishes metrics,
checkpoints asynchronously, and honors preemption.  Fault behaviours
(preemption-save, straggler flagging, restore-on-start) are all exercised by
tests/test_fault.py.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig, RunConfig
from repro.core import (AnalyticsUnitSpec, DriverSpec, Operator, SensorSpec,
                        StreamSpec)
from repro.data import corpus as corpus_mod
from repro.data import pipeline as pipe
from repro.distributed import sharding as shard

from . import optimizer as opt
from . import steps as steps_mod
from .checkpoint import CheckpointManager
from .fault import PreemptionHandler, StepTimeMonitor


@dataclasses.dataclass
class TrainerConfig:
    global_batch: int = 8
    seq_len: int = 256
    ckpt_every: int = 50
    log_every: int = 10
    total_steps: int = 1000
    workdir: str = "/tmp/repro-train"
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, run: RunConfig, tcfg: TrainerConfig,
                 mesh=None, operator: Operator | None = None,
                 deploy_pipeline: bool = True, batch_stream: str = "batches"):
        """``deploy_pipeline=False`` skips the built-in v1 spec-style data
        pipeline: the caller deploys its own (e.g. a v2 fluent-DSL app, see
        examples/train_lm.py) onto ``operator`` and the Trainer just
        subscribes to ``batch_stream`` — the paper's stream-reuse claim
        applied to the training loop itself."""
        self.cfg = cfg
        self.run = run
        self.tcfg = tcfg
        self.mesh = mesh or jax.make_mesh((1, 1), ("data", "model"))
        self.op = operator or Operator(reconcile_interval_s=0.2)
        self._own_operator = operator is None
        self.preemption = PreemptionHandler()
        self.monitor = StepTimeMonitor()
        self.ckpt = CheckpointManager(tcfg.workdir + "/ckpt")
        self.metrics_log: list[dict] = []
        self.step = 0
        if deploy_pipeline:
            self._deploy_pipeline()
        else:
            self._batch_sub = self.op.subscribe(batch_stream, name="trainer",
                                                maxsize=4)
        self._build_device_au()

    # ------------------------------------------------------------- pipeline
    def _deploy_pipeline(self) -> None:
        t = self.tcfg
        self.op.register_driver(DriverSpec(
            name="corpus", logic=corpus_mod.corpus_driver,
            config_schema=corpus_mod.CORPUS_CONFIG,
            output_schema=corpus_mod.CORPUS_SCHEMA))
        self.op.register_analytics_unit(AnalyticsUnitSpec(
            name="packer", logic=pipe.packer_au,
            config_schema=pipe.PACKER_CONFIG,
            output_schema=pipe.PACKED_SCHEMA, max_instances=4))
        self.op.register_analytics_unit(AnalyticsUnitSpec(
            name="batcher", logic=pipe.batcher_au,
            config_schema=pipe.BATCHER_CONFIG,
            output_schema=pipe.BATCH_SCHEMA, max_instances=1))
        self.op.register_sensor(SensorSpec(
            name="docs", driver="corpus",
            config={"vocab": self.cfg.vocab, "seed": t.seed}), start=False)
        self.op.create_stream(StreamSpec(
            name="sequences", analytics_unit="packer", inputs=("docs",),
            config={"seq_len": t.seq_len}))
        # batcher must be a single instance (it accumulates across messages)
        self.op.create_stream(StreamSpec(
            name="batches", analytics_unit="batcher", inputs=("sequences",),
            config={"batch": t.global_batch}, fixed_instances=1))
        self.op.start()
        self._batch_sub = self.op.subscribe("batches", name="trainer",
                                            maxsize=4)
        self.op.start_pending_sensors()

    # ------------------------------------------------------------ device AU
    def _build_device_au(self) -> None:
        batch_shape = {
            "tokens": jax.ShapeDtypeStruct(
                (self.tcfg.global_batch, self.tcfg.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct(
                (self.tcfg.global_batch, self.tcfg.seq_len), jnp.int32),
        }
        self.train_step, (params_shape, opt_shape) = steps_mod.jit_train_step(
            self.cfg, self.run, self.mesh, batch_shape,
            total_steps=self.tcfg.total_steps)
        self.params_shape = params_shape
        pspecs = shard.param_specs(params_shape, self.cfg, self.run, self.mesh)
        self.param_shardings = shard.to_shardings(pspecs, self.mesh)
        self.batch_shardings = shard.to_shardings(
            shard.batch_specs(batch_shape, self.mesh), self.mesh)

    # ------------------------------------------------------------ lifecycle
    def init_or_restore(self) -> None:
        state_like = {
            "params": self.params_shape,
            "opt": steps_mod.abstract_opt_state(self.params_shape, self.run),
        }
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, manifest = self.ckpt.restore(state_like)
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = manifest["step"]
            return
        with jax.default_device(jax.devices()[0]):
            self.params = models.init(
                jax.random.PRNGKey(self.tcfg.seed), self.cfg)
            self.opt_state = opt.init_opt_state(self.params, self.run)
        self.params = jax.device_put(self.params, self.param_shardings)

    def _next_batch(self, timeout: float = 30.0) -> dict | None:
        msg = self._batch_sub.next(timeout=timeout)
        if msg is None:
            return None
        return jax.device_put(
            {"tokens": msg.payload["tokens"], "labels": msg.payload["labels"]},
            self.batch_shardings)

    # ------------------------------------------------------------------- run
    def run_steps(self, n: int) -> list[dict]:
        out = []
        for _ in range(n):
            if self.preemption.preempted:
                self.ckpt.save(self.step, {"params": self.params,
                                           "opt": self.opt_state},
                               blocking=True, meta={"preempted": True})
                break
            batch = self._next_batch()
            if batch is None:
                break
            t0 = time.monotonic()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.monotonic() - t0
            self.step += 1
            straggler = self.monitor.record(self.step, dt)
            metrics.update(step=self.step, step_time_s=dt,
                           straggler=straggler)
            self.metrics_log.append(metrics)
            out.append(metrics)
            if self.step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.step, {"params": self.params,
                                           "opt": self.opt_state})
        return out

    def close(self) -> None:
        self.ckpt.wait()
        if self._own_operator:
            self.op.shutdown()
