"""grok-1-314b — MoE, 8 experts top-2, gated expert MLP.
[hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "grok-1-314b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab=131072, head_dim=128,
        moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
        rope_theta=1e4, act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=256, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.5),
        rope_theta=1e4, act="silu",
    )
