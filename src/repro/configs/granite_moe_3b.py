"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8, tiny experts.
[hf:ibm-granite/granite-3.0-3b-a800m-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

ARCH_ID = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155, head_dim=64,
        moe=MoEConfig(num_experts=40, top_k=8, capacity_factor=1.25),
        tie_embeddings=True, rope_theta=1e4, act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab=256, head_dim=12,
        moe=MoEConfig(num_experts=8, top_k=4, capacity_factor=1.5),
        tie_embeddings=True, rope_theta=1e4, act="silu",
    )
