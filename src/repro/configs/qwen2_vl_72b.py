"""qwen2-vl-72b — VLM backbone with M-RoPE; patch frontend is a STUB
(input_specs provides token ids + M-RoPE position ids). [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2-vl-72b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128,
        mrope=True, mrope_sections=(16, 24, 24),
        rope_theta=1e6, act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=32,
        mrope=True, mrope_sections=(4, 6, 6),
        rope_theta=1e4, act="silu",
    )
