"""Architecture registry: ``--arch <id>`` resolution + shape sets.

Usage::

    from repro.configs import get_config, get_smoke_config, ARCHS
    cfg = get_config("qwen3-32b")
"""
from __future__ import annotations

import importlib

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                   SHAPES_BY_NAME, TRAIN_4K, ModelConfig, RunConfig,
                   ShapeConfig)

_MODULES = {
    "qwen3-32b": "qwen3_32b",
    "minitron-4b": "minitron_4b",
    "qwen3-14b": "qwen3_14b",
    "granite-34b": "granite_34b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "grok-1-314b": "grok_1_314b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "mamba2-370m": "mamba2_370m",
    "zamba2-2.7b": "zamba2_2p7b",
}

ARCHS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def shapes_for(arch: str) -> list[ShapeConfig]:
    """The assigned shape cells for an arch, applying the skip rules:

    * long_500k only for sub-quadratic archs (SSM/hybrid) — full-attention
      archs skip it (see DESIGN.md §5).
    """
    cfg = get_config(arch)
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out


def skipped_shapes_for(arch: str) -> list[tuple[str, str]]:
    """(shape, reason) cells excluded for this arch."""
    cfg = get_config(arch)
    if not cfg.sub_quadratic:
        return [("long_500k", "skip(full-attn): 500k-token KV with full "
                              "attention is the quadratic regime this shape "
                              "excludes")]
    return []


__all__ = [
    "ARCHS", "get_config", "get_smoke_config", "shapes_for",
    "skipped_shapes_for", "ModelConfig", "RunConfig", "ShapeConfig",
    "ALL_SHAPES", "SHAPES_BY_NAME", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K",
]
