"""qwen3-32b — dense, qk_norm, GQA. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        d_ff=25600, vocab=151936,
        qk_norm=True, rope_theta=1e6, act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        qk_norm=True, rope_theta=1e4, act="silu",
    )
