"""mamba2-370m — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "mamba2-370m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1,
                      conv_width=4, chunk_size=256),
        tie_embeddings=True, sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=256,
        ssm=SSMConfig(state_dim=16, head_dim=8, expand=2, n_groups=1,
                      conv_width=4, chunk_size=16),
        tie_embeddings=True, sub_quadratic=True,
    )
