"""zamba2-2.7b — hybrid: Mamba2 backbone + weight-shared attention block
applied every 6 layers. [arXiv:2411.15242; hf]

Adaptation note (DESIGN.md): the released model interleaves two shared
blocks with per-invocation LoRA deltas; we implement one fully-shared block
per period, which preserves the defining property (attention params are
O(1) in depth) with the assigned dims."""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

ARCH_ID = "zamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1,
                      conv_width=4, chunk_size=256),
        hybrid=HybridConfig(period=6),
        rope_theta=1e4, act="silu", sub_quadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        ssm=SSMConfig(state_dim=16, head_dim=8, expand=2, n_groups=1,
                      conv_width=4, chunk_size=16),
        hybrid=HybridConfig(period=2),
        rope_theta=1e4, act="silu", sub_quadratic=True,
    )
