"""granite-34b — dense code model, MQA (kv=1), GPTBigCode-style GeLU MLP.
[arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "granite-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152, head_dim=128,
        rope_theta=1e5, act="gelu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=256, head_dim=16,
        rope_theta=1e4, act="gelu",
    )
