"""Model / run configuration system.

One :class:`ModelConfig` covers all assigned architecture families (dense,
MoE, SSM, hybrid, enc-dec audio, VLM backbone).  Per-arch files in this
package export ``config()`` with the exact assigned dims, plus
``smoke_config()`` — a reduced same-family config for CPU tests.

Shapes are :class:`ShapeConfig`; the four assigned shape sets are constants.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) hyper-parameters."""

    state_dim: int = 128          # N
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    n_groups: int = 1             # B/C groups (GVA)
    conv_width: int = 4
    chunk_size: int = 256         # SSD chunk length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: shared attention block applied every `period` layers."""

    period: int = 6               # one shared-attn invocation per 6 mamba layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope: bool = False               # Qwen2-VL M-RoPE (3-section t/h/w)
    mrope_sections: tuple = (16, 24, 24)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                 # silu => SwiGLU MLP; gelu => GeLU MLP
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    # enc-dec (whisper): n_layers applies to BOTH encoder and decoder stacks
    encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper frame count after conv stub
    # numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # notes for DESIGN/roofline
    sub_quadratic: bool = False       # can run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline + sanity checks)."""
        d, v, hd = self.d_model, self.vocab, self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        att = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.qk_norm:
            att += 2 * hd
        if self.act == "silu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.moe is not None:
            mlp = mlp * self.moe.num_experts + d * self.moe.num_experts
        norms = 2 * d
        per_layer = att + mlp + norms

        if self.family == "ssm":
            per_layer = self._ssm_layer_params() + d
        elif self.family == "hybrid":
            shared = att + mlp + norms
            per_layer = self._ssm_layer_params() + d
            return emb + self.n_layers * per_layer + shared + d
        elif self.family == "encdec":
            # encoder: self-attn + mlp; decoder: self-attn + cross-attn + mlp
            enc = self.encoder_layers * (att + mlp + norms)
            dec = self.n_layers * (att + att + mlp + 3 * d)
            return emb + enc + dec + d

        return emb + self.n_layers * per_layer + d

    def _ssm_layer_params(self) -> int:
        s = self.ssm or SSMConfig()
        d = self.d_model
        d_in = s.expand * d
        nheads = d_in // s.head_dim
        conv_dim = d_in + 2 * s.n_groups * s.state_dim
        in_proj = d * (2 * d_in + 2 * s.n_groups * s.state_dim + nheads)
        return (in_proj + conv_dim * s.conv_width + nheads * 2  # A_log, D
                + d_in                                           # gated-norm weight
                + d_in * d)                                      # out_proj

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        expert = (3 if self.act == "silu" else 2) * self.d_model * self.d_ff
        inactive = self.n_layers * expert * (self.moe.num_experts - self.moe.top_k)
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Distribution + numerics knobs for a training/serving run."""

    microbatches: int = 1            # gradient-accumulation steps
    remat: str = "full"              # none | dots | full
    zero3: bool = False              # shard params over the data axis (FSDP)
    seq_shard_kv: bool = True        # decode: shard KV cache seq over model axis
    seq_parallel: bool = False       # shard activation seq dim over model axis
    expert_axis: str | None = None   # MoE expert-parallel axis (None = expert-TP)
    moe_group_size: int = 2048       # GShard expert-group size (dispatch is
                                     # O(S·C)=O(S²) per group -> smaller is cheaper)
    decode_carry_cache: bool = False # thread KV cache through the layer-scan
                                     # CARRY (guaranteed in-place) instead of
                                     # xs->ys (which copies the full cache)
    decode_attn_impl: str = "direct" # direct | chunked (flash-decoding scan;
                                     # never materializes [B,H,S] scores)
    grad_compression: str = "none"   # none | bf16 | int8_ef
    grad_accum_dtype: str = "float32"  # float32 | bfloat16 — microbatch grad
                                     # accumulator (bf16 halves grad-AR wire)
    attention_impl: str = "chunked"  # chunked | naive | pallas
    attention_chunk: int = 1024
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100
