"""minitron-4b — dense, pruned nemotron, GQA. [arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "minitron-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=9216, vocab=256000, head_dim=128,
        tie_embeddings=True, rope_theta=1e4, act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
        d_ff=96, vocab=512, head_dim=16,
        tie_embeddings=True, rope_theta=1e4, act="silu",
    )
