"""qwen3-14b — dense, qk_norm, GQA, head_dim 128. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen3-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab=151936, head_dim=128,
        qk_norm=True, rope_theta=1e6, act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=256, head_dim=32,
        qk_norm=True, rope_theta=1e4, act="silu",
    )
