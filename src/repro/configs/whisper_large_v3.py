"""whisper-large-v3 — enc-dec audio backbone; conv/mel frontend is a STUB
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]

Adaptation notes (DESIGN.md §2): the backbone uses RoPE for decoder positions
instead of Whisper's learned absolute embeddings — positional scheme is not
the assignment's focus; dims/heads/layers match the assigned spec (32L each
for encoder and decoder, as in the released large checkpoints)."""
from repro.configs.base import ModelConfig

ARCH_ID = "whisper-large-v3"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="encdec",
        n_layers=32, encoder_layers=32, encoder_seq=1500,
        d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab=51866,
        rope_theta=1e4, act="gelu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="encdec",
        n_layers=2, encoder_layers=2, encoder_seq=30,
        d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        rope_theta=1e4, act="gelu",
    )
