"""Training launcher: ``python -m repro.launch.train --arch qwen3-32b ...``

On this CPU container it builds a (1,1) host mesh and a REDUCED config by
default (--full uses the assigned dims — only sensible on a real slice).
On hardware, the same entry point runs under the multi-host runtime
(jax.distributed.initialize is called when JAX_COORDINATOR is set) with the
production mesh from repro.launch.mesh.
"""
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true",
                    help="use the assigned full config (real hardware)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workdir", default="/tmp/repro-launch-train")
    ap.add_argument("--data-axis", type=int, default=0,
                    help="mesh data-axis size (0 = all devices)")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8_ef"])
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):  # multi-host entry
        import jax
        jax.distributed.initialize()

    import jax

    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import RunConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    n_dev = len(jax.devices())
    data = args.data_axis or max(1, n_dev // args.model_axis)
    mesh = jax.make_mesh((data, args.model_axis), ("data", "model"))
    run = RunConfig(attention_impl="chunked", attention_chunk=256,
                    remat="full" if args.full else "none",
                    microbatches=args.microbatches,
                    grad_compression=args.grad_compression,
                    zero3=args.full)
    tcfg = TrainerConfig(global_batch=args.batch, seq_len=args.seq,
                         ckpt_every=25, total_steps=args.steps,
                         workdir=args.workdir)
    tr = Trainer(cfg, run, tcfg, mesh=mesh)
    tr.init_or_restore()
    print(f"arch={args.arch} params={cfg.param_count()/1e6:.1f}M "
          f"mesh=({data},{args.model_axis}) resume_step={tr.step}")
    while tr.step < args.steps:
        got = tr.run_steps(min(10, args.steps - tr.step))
        if not got:
            break
        m = got[-1]
        print(f"step {m['step']:5d} loss {m['loss']:.4f} "
              f"{m['step_time_s']*1e3:.0f}ms")
    tr.close()


if __name__ == "__main__":
    main()
