"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.

Mesh shapes (TPU v5e pods):
  single-pod:  (data=16, model=16)            — 256 chips
  multi-pod:   (pod=2, data=16, model=16)     — 512 chips, 'pod' is the
               cross-pod (DCN) data-parallel axis; gradient reduction is
               hierarchical (reduce-scatter within pod, all-reduce across).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data*model} devices, "
                         f"have {n}")
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants for roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW_PER_LINK = 50e9         # bytes/s per direction per link
CHIPS_PER_POD = 256
