"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run lowers against
these.  Modality frontends are STUBS per the assignment: whisper gets
precomputed frame embeddings [B, F, D]; qwen2-vl gets token ids + M-RoPE
position ids [B, 3, S] (patch embedder not modelled).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig, ShapeConfig


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((B, S), "int32"),
        "labels": _sds((B, S), "int32"),
    }
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                               cfg.activation_dtype)
    if cfg.mrope:
        batch["positions"] = _sds((B, 3, S), "int32")
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), "int32")}
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                               cfg.activation_dtype)
    if cfg.mrope:
        batch["positions"] = _sds((B, 3, S), "int32")
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    return {
        "tokens": _sds((B, 1), "int32"),
        "seq_lens": _sds((B,), "int32"),
    }


def decode_cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(functools.partial(
        models.init_cache, cfg, shape.global_batch, shape.seq_len))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """All device-input stand-ins for one (arch × shape) cell."""
    if shape.kind == "train":
        return {"batch": train_input_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_input_specs(cfg, shape)}
    return {"batch": decode_input_specs(cfg, shape),
            "cache": decode_cache_specs(cfg, shape)}
