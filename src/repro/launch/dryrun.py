import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the 512-chip production meshes.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware:

  * (16, 16) single-pod mesh  — 256 chips; the roofline table reads this.
  * (2, 16, 16) multi-pod mesh — 512 chips; proves the 'pod' axis shards.

For each cell: jit(step).lower(**input_specs).compile(), then record
memory_analysis (fits-on-chip proof), cost_analysis (FLOPs/bytes) and the
collective schedule parsed from the optimized HLO -> JSON in
experiments/dryrun/ consumed by EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod | --both]
"""
import argparse
import gzip
import json
import sys
import time
import traceback

import jax  # noqa: F401  (imported for effect: locks the fake device count)

from repro.configs import (ARCHS, SHAPES_BY_NAME, get_config, shapes_for,
                           skipped_shapes_for)
from repro.launch import presets
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as roofline
from repro.train import steps


def _mesh_desc(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())


def build_lowered(arch: str, shape_name: str, mesh, run=None):
    """Lower one cell; returns (lowered, cfg, run, n_chips)."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    run = run or presets.run_preset(cfg, shape)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        fn, (params_shape, opt_shape) = steps.jit_train_step(
            cfg, run, mesh, specs["batch"])
        lowered = fn.lower(params_shape, opt_shape, specs["batch"])
    elif shape.kind == "prefill":
        fn, params_shape = steps.jit_prefill_step(cfg, run, mesh,
                                                  specs["batch"])
        lowered = fn.lower(params_shape, specs["batch"])
    else:
        fn, (params_shape, cache_shape) = steps.jit_decode_step(
            cfg, run, mesh, shape.global_batch, shape.seq_len, specs["batch"])
        lowered = fn.lower(params_shape, specs["cache"], specs["batch"])
    return lowered, cfg, run, mesh.devices.size


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "experiments/dryrun", run=None,
             tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES_BY_NAME[shape_name]
    t0 = time.monotonic()
    lowered, cfg, run, chips = build_lowered(arch, shape_name, mesh, run)
    t1 = time.monotonic()
    compiled = lowered.compile()
    t2 = time.monotonic()

    mem = compiled.memory_analysis()
    r = roofline.analyze(
        compiled, arch=arch, shape_name=shape_name, mesh_desc=_mesh_desc(mesh),
        chips=chips, model_flops=roofline.model_flops_for(cfg, shape),
        notes=f"remat={run.remat} mb={run.microbatches} zero3={run.zero3}")
    result = r.to_dict()
    result.update({
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory_analysis": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "ok": True,
    })
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    cell = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}{suffix}"
    with open(os.path.join(out_dir, f"{cell}.json"), "w") as f:
        json.dump(result, f, indent=2)
    # cache the optimized HLO so roofline models can be re-derived without
    # recompiling (perf-iteration loop reads these)
    hlo_dir = os.path.join(os.path.dirname(out_dir.rstrip("/")), "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    with gzip.open(os.path.join(hlo_dir, f"{cell}.hlo.gz"), "wt") as f:
        f.write(compiled.as_text())
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES_BY_NAME), default=None)
    ap.add_argument("--all", action="store_true", help="sweep all cells")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod meshes")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args(argv)

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCHS:
            for shape in shapes_for(arch):
                cells.append((arch, shape.name))
            for shape_name, reason in skipped_shapes_for(arch):
                print(f"SKIP {arch} × {shape_name}: {reason}")
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both else [args.multi_pod]
    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            label = f"{arch} × {shape_name} × {'pod2' if mp else 'pod1'}"
            try:
                r = run_cell(arch, shape_name, multi_pod=mp,
                             out_dir=args.out_dir)
                print(f"OK   {label}: "
                      f"flops/dev={r['flops_per_device']:.3e} "
                      f"bytes/dev={r['bytes_per_device']:.3e} "
                      f"wire/dev={r['wire_bytes_per_device']:.3e} "
                      f"bottleneck={r['bottleneck']} "
                      f"peak_mem={r['peak_memory_bytes']/2**30:.2f}GiB "
                      f"(lower {r['lower_s']}s compile {r['compile_s']}s)")
                sys.stdout.flush()
            except Exception:
                failures += 1
                print(f"FAIL {label}\n{traceback.format_exc()}")
                sys.stdout.flush()
                if not args.continue_on_error:
                    return 1
    print(f"dry-run complete: {len(cells)*len(meshes)-failures} ok, "
          f"{failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
