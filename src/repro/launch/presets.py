"""Per-(arch × shape) RunConfig presets: the distribution knobs the platform
operator picks for each cell (microbatching, FSDP, remat, cache sharding).

These are the BASELINE settings recorded in EXPERIMENTS.md §Roofline; the
hillclimb iterates on three cells from here.  Rationale per knob:

* zero3 (FSDP over 'data'): on for training runs of >10B-param archs —
  otherwise optimizer state per chip exceeds v5e HBM.  Off for serving
  (per-layer param all-gathers are latency poison) except grok-1, whose
  633 GB of bf16 experts cannot fit 16-way TP alone even for inference.
* microbatches: sized so saved layer inputs (#layers × B_local × S × D × 2B)
  stay under ~6 GB/chip with full remat.
* remat: 'full' for train, 'none' for inference.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig

_TRAIN_MICROBATCH = {
    "qwen3-32b": 16,
    "minitron-4b": 4,
    "qwen3-14b": 16,
    "granite-34b": 16,
    "whisper-large-v3": 4,
    "qwen2-vl-72b": 16,
    "grok-1-314b": 16,
    "granite-moe-3b-a800m": 4,
    "mamba2-370m": 4,
    "zamba2-2.7b": 8,
}

_ZERO3_TRAIN = {"qwen3-32b", "qwen3-14b", "granite-34b", "qwen2-vl-72b",
                "grok-1-314b"}
_ZERO3_SERVE = {"grok-1-314b"}


def run_preset(cfg: ModelConfig, shape: ShapeConfig) -> RunConfig:
    if shape.kind == "train":
        return RunConfig(
            microbatches=_TRAIN_MICROBATCH.get(cfg.name, 4),
            remat="full",
            zero3=cfg.name in _ZERO3_TRAIN,
            attention_impl="chunked",
            attention_chunk=1024,
        )
    if shape.kind == "prefill":
        return RunConfig(
            microbatches=1, remat="none",
            zero3=cfg.name in _ZERO3_SERVE,
            attention_impl="chunked", attention_chunk=1024,
        )
    # decode
    return RunConfig(
        microbatches=1, remat="none",
        zero3=cfg.name in _ZERO3_SERVE,
        seq_shard_kv=True,
        attention_impl="chunked", attention_chunk=1024,
    )


def with_overrides(run: RunConfig, **kw) -> RunConfig:
    return dataclasses.replace(run, **kw)
