"""Serving launcher: ``python -m repro.launch.serve --arch qwen3-32b ...``

Reduced config on CPU (--full for real slices).  Drives the continuous-
batching engine with a synthetic request stream and prints latency stats.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro import models
    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import RunConfig
    from repro.serve import ServeEngine

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    run = RunConfig(attention_impl="chunked", attention_chunk=256,
                    remat="none")
    params = models.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, run, params, n_slots=args.slots,
                      max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, min(64, args.max_seq // 2)))
        eng.submit(f"req-{i:04d}", list(rng.integers(1, cfg.vocab, plen)),
                   max_new_tokens=args.max_new)
    done = eng.run_until_idle()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    ttfts = sorted((r.first_token_at - r.arrived) * 1e3 for r in done)
    print(f"arch={args.arch} served={len(done)} tokens={toks} "
          f"tok/s={toks/dt:.0f} ttft_p50={ttfts[len(ttfts)//2]:.0f}ms "
          f"ttft_p99={ttfts[int(len(ttfts)*0.99)]:.0f}ms")
    print("engine:", eng.metrics)


if __name__ == "__main__":
    main()
