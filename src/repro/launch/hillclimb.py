import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede all other imports (see dryrun.py)

"""Perf hillclimb driver — hypothesis -> change -> re-lower -> re-analyse.

Three cells (chosen per the assignment from the baseline roofline table):

  A. granite-moe-3b-a800m × train_4k — WORST roofline fraction (0.1%,
     useful-FLOPs ratio 0.02: the dense [G,S,E,C] dispatch dominates tiny
     experts).  Iterations target the dominant memory/compute waste.
  B. grok-1-314b × train_4k — MOST COLLECTIVE-BOUND (75 s collective vs
     26 s compute at baseline).  Iterations target wire bytes.
  C. qwen3-32b × decode_32k — most representative of the paper's technique
     (the serving/stream-exchange path).  Iterations target HBM traffic.

Each variant compiles the cell with RunConfig overrides and records the
three roofline terms to experiments/perf/<cell>__<tag>.json.  The narrative
log (hypothesis / before / after / verdict) lives in EXPERIMENTS.md §Perf.

Usage:
  python -m repro.launch.hillclimb --cell A [--variant name | --all]
"""
import argparse
import dataclasses
import sys
import traceback

from repro.configs import SHAPES_BY_NAME, get_config
from repro.launch import presets
from repro.launch.dryrun import run_cell

CELLS = {
    "A": ("granite-moe-3b-a800m", "train_4k"),
    "B": ("grok-1-314b", "train_4k"),
    "C": ("qwen3-32b", "decode_32k"),
}

# variant name -> RunConfig overrides
VARIANTS = {
    "A": {
        "baseline": {},
        "group512": {"moe_group_size": 512},
        "group256": {"moe_group_size": 256},
        "group128": {"moe_group_size": 128},
        "group512_mb8": {"moe_group_size": 512, "microbatches": 8},
        "group512_dots": {"moe_group_size": 512, "remat": "dots"},
        # lean_* run AFTER the moe.py lean-routing rewrite (bool/i32
        # intermediates instead of f32 one-hots); same RunConfig as their
        # pre-rewrite counterparts -> isolates the code change
        "lean2048": {},
        "lean512": {"moe_group_size": 512},
        "lean512_mb8": {"moe_group_size": 512, "microbatches": 8},
    },
    "B": {
        "baseline": {},
        "dots": {"remat": "dots"},
        "seqpar": {"seq_parallel": True},
        "dots_seqpar": {"remat": "dots", "seq_parallel": True},
        "expert_data": {"expert_axis": "data"},
        "mb8": {"microbatches": 8},
        "mb8_dots": {"microbatches": 8, "remat": "dots"},
        "gacc_bf16": {"grad_accum_dtype": "bfloat16"},
        "mb8_noremat": {"microbatches": 8, "remat": "none"},
        "mb8_gacc_bf16": {"microbatches": 8,
                          "grad_accum_dtype": "bfloat16"},
    },
    "C": {
        "baseline": {},
        "carry_cache": {"decode_carry_cache": True},
        "carry_noseqshard": {"decode_carry_cache": True,
                             "seq_shard_kv": False},
        "chunked_attn": {"decode_attn_impl": "chunked",
                         "attention_chunk": 2048},
        "chunked_attn_512": {"decode_attn_impl": "chunked",
                             "attention_chunk": 512},
    },
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), required=True)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="experiments/perf")
    args = ap.parse_args()

    arch, shape_name = CELLS[args.cell]
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    base_run = presets.run_preset(cfg, shape)
    names = list(VARIANTS[args.cell]) if args.all else [args.variant]
    for name in names:
        overrides = VARIANTS[args.cell][name]
        run = dataclasses.replace(base_run, **overrides)
        try:
            r = run_cell(arch, shape_name, multi_pod=False,
                         out_dir=args.out_dir, run=run,
                         tag=f"{args.cell}-{name}")
            print(f"{args.cell}/{name}: compute={r['compute_s']:.3f}s "
                  f"memory={r['memory_s']:.3f}s "
                  f"collective={r['collective_s']:.3f}s "
                  f"bottleneck={r['bottleneck']} "
                  f"useful={r['useful_flops_ratio']:.3f} "
                  f"frac={r['roofline_fraction']*100:.2f}% "
                  f"peak={r['peak_memory_bytes']/2**30:.1f}GiB")
            sys.stdout.flush()
        except Exception:
            print(f"{args.cell}/{name}: FAILED\n{traceback.format_exc()}")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
