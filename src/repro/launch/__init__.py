"""Launchers: mesh definitions, dry-run, train/serve drivers.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only in a
fresh process (its __main__ entry).  Everything else here is import-safe.
"""
