"""Application builder — succinct specification of a DataX app (paper §2).

"Developers define and register objects like sensors, drivers, streams,
analytics units, actuators, and gadgets, all of which enable succinct
specification of the overall application pipeline."

:class:`Application` collects entity specs declaratively and deploys them onto
an :class:`~repro.core.operator.Operator` in dependency order; it also
*validates the whole graph before touching the operator* (dangling inputs,
cycles, name clashes) so a bad app never half-deploys — the app-level face of
the coherence guarantees.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Iterable, Mapping

from .entities import (ActuatorSpec, AnalyticsUnitSpec, DatabaseSpec,
                       DriverSpec, GadgetSpec, SensorSpec, StreamSpec)
from .operator import CoherenceError, Operator


class AppValidationError(RuntimeError):
    pass


@dataclasses.dataclass
class Application:
    """A declarative DataX application: entities + the stream graph."""

    name: str
    drivers: list[DriverSpec] = dataclasses.field(default_factory=list)
    analytics_units: list[AnalyticsUnitSpec] = dataclasses.field(default_factory=list)
    actuators: list[ActuatorSpec] = dataclasses.field(default_factory=list)
    sensors: list[SensorSpec] = dataclasses.field(default_factory=list)
    streams: list[StreamSpec] = dataclasses.field(default_factory=list)
    gadgets: list[GadgetSpec] = dataclasses.field(default_factory=list)
    databases: list[DatabaseSpec] = dataclasses.field(default_factory=list)
    #: AU names opted into upgrade-in-place at deploy time (value: optional
    #: config converter, §4) — populated by the v2 DSL's ``.via(upgrade=...)``.
    upgrades: Mapping[str, Callable[[dict], dict] | None] = \
        dataclasses.field(default_factory=dict)
    #: Subjects promised to external subscribers (the v2 DSL's ``.tap()``
    #: set).  Carried on the compiled graph so deploy-time diagnostics
    #: (``datax check``) judge tapped streams the same way the build did.
    taps: tuple = ()

    # -- fluent builders ------------------------------------------------------
    def driver(self, spec: DriverSpec) -> "Application":
        self.drivers.append(spec)
        return self

    def analytics_unit(self, spec: AnalyticsUnitSpec) -> "Application":
        self.analytics_units.append(spec)
        return self

    def actuator(self, spec: ActuatorSpec) -> "Application":
        self.actuators.append(spec)
        return self

    def sensor(self, spec: SensorSpec) -> "Application":
        self.sensors.append(spec)
        return self

    def stream(self, spec: StreamSpec) -> "Application":
        self.streams.append(spec)
        return self

    def gadget(self, spec: GadgetSpec) -> "Application":
        self.gadgets.append(spec)
        return self

    def database(self, spec: DatabaseSpec) -> "Application":
        self.databases.append(spec)
        return self

    # -- validation -------------------------------------------------------------
    def validate(self, *, external_streams: Iterable[str] = ()) -> list[str]:
        """Whole-graph checks; returns topologically-ordered stream names.

        ``external_streams`` are streams already registered on the target
        operator (the paper's reuse of third-party streams, §3).
        """
        errors: list[str] = []
        driver_names = {d.name for d in self.drivers}
        au_names = {a.name for a in self.analytics_units}
        act_names = {a.name for a in self.actuators}
        producers = set(external_streams)

        names = [s.name for s in self.sensors] + [s.name for s in self.streams]
        dupes = {n for n in names if names.count(n) > 1}
        if dupes:
            errors.append(f"duplicate stream/sensor names: {sorted(dupes)}")

        for s in self.sensors:
            if s.driver not in driver_names:
                errors.append(f"sensor {s.name!r}: unknown driver {s.driver!r}")
            producers.add(s.name)

        # topo-sort the derived streams
        pending = {s.name: s for s in self.streams}
        order: list[str] = []
        progressed = True
        while pending and progressed:
            progressed = False
            for name, s in list(pending.items()):
                if all(i in producers for i in s.inputs):
                    if s.analytics_unit not in au_names:
                        errors.append(
                            f"stream {name!r}: unknown analytics unit "
                            f"{s.analytics_unit!r}")
                    producers.add(name)
                    order.append(name)
                    del pending[name]
                    progressed = True
        if pending:
            for name, s in pending.items():
                missing = [i for i in s.inputs if i not in producers]
                errors.append(f"stream {name!r}: unresolvable inputs {missing} "
                              f"(dangling or cyclic)")

        for g in self.gadgets:
            if g.actuator not in act_names:
                errors.append(f"gadget {g.name!r}: unknown actuator {g.actuator!r}")
            for i in g.inputs:
                if i not in producers:
                    errors.append(f"gadget {g.name!r}: unknown input {i!r}")

        if errors:
            raise AppValidationError(f"app {self.name!r}: " + "; ".join(errors))
        return order

    # -- deployment ---------------------------------------------------------------
    def deploy(self, op: Operator, *, start_sensors: bool = True) -> None:
        """Validate, then register everything in dependency order.

        ``start_sensors=False`` leaves the sensors registered but idle so the
        caller can attach external subscriptions first (streams are lossy —
        there is no replay); fire them with ``op.start_pending_sensors()``.
        """
        order = self.validate(external_streams=op.registered_streams())
        # record the datax-check diagnostic summary on the operator BEFORE
        # spawning anything, so instances pick up their stream's findings
        # (sidecar metrics) and ops tooling sees what was flagged even if a
        # later registration step fails.  Lazy import: analyze imports this
        # module.
        from .analyze import analyze_application
        try:
            diagnostics = analyze_application(self, taps=self.taps)
        except Exception:  # never let the audit break a deploy
            diagnostics = []
        op.record_diagnostics(self.name, diagnostics)
        for db in self.databases:
            op.create_database(db)
        for d in self.drivers:
            op.register_driver(d)
        installed = op.describe()["analytics_units"] if self.upgrades else {}
        for a in self.analytics_units:
            if a.name in self.upgrades and a.name in installed:
                # re-compose to the Operator's §4 upgrade path: cascades to
                # running streams, refused unless schema/converter-compatible
                op.upgrade_analytics_unit(a, converter=self.upgrades[a.name])
            else:
                op.register_analytics_unit(a)
        for a in self.actuators:
            op.register_actuator(a)
        for s in self.sensors:
            # deferred start: no data flows until every consumer subscribed
            op.register_sensor(s, start=False)
        by_name = {s.name: s for s in self.streams}
        for name in order:
            op.create_stream(by_name[name])
        for g in self.gadgets:
            op.register_gadget(g)
        if start_sensors:
            op.start_pending_sensors()

    def undeploy(self, op: Operator) -> None:
        """Tear down in reverse dependency order (coherence-safe)."""
        for g in self.gadgets:
            with contextlib.suppress(Exception):
                op.delete_gadget(g.name)
        order = self.validate(external_streams=op.registered_streams())
        for name in reversed(order):
            with contextlib.suppress(CoherenceError):
                op.delete_stream(name)
        for s in self.sensors:
            with contextlib.suppress(CoherenceError):
                op.delete_sensor(s.name)

    def loc_footprint(self) -> int:
        """#entities — proxy for the paper's programmer-productivity claim."""
        return (len(self.drivers) + len(self.analytics_units)
                + len(self.actuators) + len(self.sensors)
                + len(self.streams) + len(self.gadgets) + len(self.databases))
