"""Chain-fusion compiler pass + device executor (TPU adaptation).

DataX's promise is that the runtime "automatically sets up appropriate data
communication mechanisms" for the declared graph.  For HOST analytics units the
right mechanism is the message bus; for a *linear chain* of DEVICE-placement
AUs the right mechanism is no communication at all — the chain should be one
jitted program on the mesh, with interior hops as in-program values.

This module is the first real compiler pass between the fluent API and the
runtime.  It operates on the compiled v1 :class:`~.app.Application` spec graph
(so v1 spec-style apps benefit too):

1. **Segment detection** (:func:`plan_segments`) — maximal linear runs of
   streams whose AU is ``Placement.DEVICE``, single-input, stateless, and
   whose interior streams have exactly one consumer.  Fusion barriers:

   * ``.window`` / ``fuse`` combinators (stateful / multi-input — never
     DEVICE, so they stop a chain structurally);
   * multi-subscriber taps — an interior stream consumed by a second stream
     or a gadget must stay on the bus, so the segment splits there;
   * explicit taps (:meth:`StreamHandle.tap` / the ``taps`` argument) — the
     stream is promised to external subscribers and must remain a bus subject;
   * fixed instance counts > 1 (fusing would change scaling semantics).

2. **Collapse** (:func:`fuse_application`) — each segment of length >= 2 is
   replaced by one synthetic fused AU + one stream named after the segment
   exit.  Only the entry and exit edges touch the bus; interior subjects are
   never registered.  Synthetic combinator AUs orphaned by the collapse are
   garbage-collected; declared AUs stay in the catalog.

3. **Execution** (:func:`make_fused_logic`) — the fused AU's factory
   instantiates every stage factory (stage configs resolved at fusion time)
   and chains them in-process.  When jax is importable, *every* stage
   carries a ``pure_fn``, and the backend warrants it (:data:`JIT_MODE` —
   accelerators by default), the stages are composed into a single
   ``jax.jit`` program (:func:`repro.kernels.ops.jit_chain`); payloads move
   to the device once at segment entry and back once at exit.  The device
   path degrades transparently: no jax, a CPU-only backend, a stage without
   a pure_fn, or a payload/stage that fails to trace (impure, non-numeric
   fields) → the same chain runs host-composed, bit-identical to per-hop bus
   execution, still with zero interior bus hops.  A payload-local problem
   (a single non-numeric message) falls back for that message only; the
   device program stays live (``device_fallbacks`` counts them in sidecar
   metrics) — only a genuine trace failure demotes the unit permanently.

4. **Batched execution** — under backlog the Executor drains a mailbox
   burst and hands it to ``process_batch``: the whole burst is stacked
   field-wise (one host->device transfer), run through ONE vmapped program
   (:func:`repro.kernels.ops.jit_chain_batched`, per-message keep mask for
   predicated filters) and unstacked once — amortizing the per-message XLA
   dispatch that makes per-message jit slower than the host chain on CPU.
   Bursts are bounded by ``.scaled(max_batch=)`` (default
   :data:`DEFAULT_MAX_BATCH`) and padded to power-of-two sizes so at most
   log2(max_batch) batch shapes compile; ragged / mixed-shape / non-numeric
   bursts degrade per-message, bit-identical to the host chain.

5. **Mesh-sharded execution** — when more than one device is visible
   (:func:`fusion_mesh` — a 1-D ``data`` mesh over ``jax.local_devices()``,
   disable with ``DATAX_FUSION_MESH=0``) and the padded burst divides the
   mesh, the burst runs through the SPMD-partitioned program instead
   (:func:`repro.kernels.ops.jit_chain_sharded`): each field is committed
   to a ``NamedSharding`` whose leading burst dim splits over the data
   axis — trailing dims follow the stream schema's per-field
   :class:`~.schema.ShardSpec` hints via
   :func:`repro.distributed.sharding.burst_spec` — so every device
   computes its slice of the burst.  vmap rows are independent, so the
   sharded path is bit-identical to the single-device batched program; any
   indivisible burst (and any sharded-lowering failure) transparently
   stays on / returns to the single-device path.  Two ride-alongs:

   * **device residency** — a segment whose exit feeds ANOTHER fused
     segment's entry emits its array fields as :class:`ResidentArray`
     rows (plain ndarrays that remember the stacked device burst they
     came from); when the downstream unit re-stacks an intact burst it
     reuses the device array directly and the linked hop pays zero
     host->device transfer (``resident_links`` in sidecar metrics);
   * **burst autotune** — streams that declare no ``max_batch`` start at
     :data:`DEFAULT_MAX_BATCH` and double their ceiling (up to
     :data:`AUTOTUNE_MAX_BATCH`) after :data:`AUTOTUNE_STREAK` consecutive
     ceiling-filling bursts — sustained full occupancy means the mailbox
     is backlogged and a bigger program amortizes further.  The tuner also
     runs DOWN: :data:`AUTOTUNE_DOWN_STREAK` consecutive bursts slower
     than :data:`AUTOTUNE_BUDGET_S` halve the ceiling (floor 1) — past the
     device's sweet spot a bigger burst only stretches per-message
     latency.  The Executor re-reads the tuned ceiling
     (``process.current_max_batch``) each pump.

Upgrading an individual stage AU after fusion does not cascade into already-
deployed fused units (the fused AU snapshots stage logic at build time);
redeploy the app to pick up new stage versions.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Callable, Iterable, Mapping, Sequence

import numpy as np

from .app import Application
from .entities import AnalyticsUnitSpec, Placement, StreamSpec
from .schema import StreamSchema
from .sdk import BatchInterrupted, LogicContext, is_sdk_style

try:  # the pass (host-composed path) must work without jax installed
    import jax  # noqa: F401
    _HAS_JAX = True
except Exception:  # pragma: no cover - exercised via monkeypatch in tests
    _HAS_JAX = False

#: When the fused unit uses the jitted device program vs the host-composed
#: chain (both are single-microservice, zero interior bus hops):
#:
#: * ``"auto"``   — jit only on accelerator backends (tpu/gpu).  On CPU the
#:   per-message XLA dispatch + host<->device sync costs more than the numpy
#:   math it replaces (same reasoning as kernels/ops.py interpret mode), so
#:   the host chain IS the optimal lowering there.
#: * ``"always"`` — jit whenever jax + pure stages allow (tests use this to
#:   prove jit/host bit-identity on CPU).
#: * ``"never"``  — host-composed chain only.
#:
#: Overridable via the DATAX_FUSION_JIT environment variable.
JIT_MODE = "auto"

#: Default burst ceiling for a fused unit's batched execution when the stream
#: declares no ``max_batch`` of its own (``.scaled(max_batch=)``).  Each
#: mailbox pull drains up to this many queued messages into one vmapped
#: program call; bursts are padded up to the next power of two so at most
#: log2(max_batch) batch shapes ever compile (no retrace storm).
DEFAULT_MAX_BATCH = 32

#: Ceiling for the burst autotuner.  A stream that declares no ``max_batch``
#: starts at :data:`DEFAULT_MAX_BATCH` and doubles under sustained full
#: occupancy — but never beyond this, bounding both per-burst latency and
#: the number of compiled batch shapes (log2(AUTOTUNE_MAX_BATCH) total).
AUTOTUNE_MAX_BATCH = 256

#: Consecutive ceiling-filling device bursts before the autotuner doubles
#: ``max_batch`` — one full burst can be a blip; a streak means the mailbox
#: is genuinely backlogged at the current ceiling.
AUTOTUNE_STREAK = 4

#: Per-burst drain-latency budget for the autotuner's DOWN direction.  A
#: bigger ceiling amortizes dispatch, but past the device's sweet spot it
#: only stretches the burst: every message in the burst then waits the whole
#: burst's wall time.  Bursts slower than this budget count against the
#: ceiling; :data:`AUTOTUNE_DOWN_STREAK` of them in a row halve it (one slow
#: burst can be a GC pause or a recompile — a streak is the ceiling's fault).
AUTOTUNE_BUDGET_S = 0.25

#: Consecutive over-budget device bursts before the autotuner halves the
#: ceiling (floor 1; pad shapes stay powers of two).
AUTOTUNE_DOWN_STREAK = 4


def jax_available() -> bool:
    """Gate for the jitted path (module-level so tests can monkeypatch)."""
    return _HAS_JAX


_MESH_CACHE: list = []  # memo cell: [Mesh | None] once resolved


def fusion_mesh():
    """The device mesh fused programs shard over, or None.

    A 1-D ``("data",)`` :class:`jax.sharding.Mesh` spanning every locally
    visible device — built once and cached.  None (single-device semantics)
    when jax is unavailable, when only one device is visible, or when
    ``DATAX_FUSION_MESH=0`` disables sharding outright.  CI simulates a
    multi-device host with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    import os
    if os.environ.get("DATAX_FUSION_MESH", "1") in ("0", "off", "never"):
        return None
    if not jax_available():
        return None
    if not _MESH_CACHE:
        import jax
        devices = jax.local_devices()
        if len(devices) > 1:
            from jax.sharding import Mesh
            _MESH_CACHE.append(Mesh(np.array(devices), ("data",)))
        else:
            _MESH_CACHE.append(None)
    return _MESH_CACHE[0]


def mesh_axis_names() -> tuple:
    """Axis names of the active fusion mesh (empty when single-device).

    :meth:`~.dsl.App.build` unions these with the architectural axis
    vocabulary (:data:`~.schema.KNOWN_MESH_AXES`) when validating
    :class:`~.schema.ShardSpec` hints."""
    mesh = fusion_mesh()
    return tuple(mesh.axis_names) if mesh is not None else ()


def _want_jit() -> bool:
    import os
    mode = os.environ.get("DATAX_FUSION_JIT", JIT_MODE)
    if mode == "always":
        return True
    if mode == "never":
        return False
    import jax
    return jax.default_backend() not in ("cpu",)


@dataclasses.dataclass(frozen=True)
class FusedStage:
    """One folded-in hop of a fused segment."""

    au_name: str                  # stage AU (code entity) name
    stream_name: str              # the stream this stage produced pre-fusion
    factory: Callable             # the stage AU's logic factory
    config: Mapping[str, Any]     # resolved (schema-validated) stage config
    kind: str                     # "map" | "filter" | "au"
    pure_fn: Callable | None      # payload fn for jit composition, if pure


# ---------------------------------------------------------------------------
# Segment detection
# ---------------------------------------------------------------------------

class BarrierReason(enum.Enum):
    """Why a stream stops (or never joins) a fused DEVICE segment.

    The fusion pass used to decide barriers inline and throw the reason
    away; now every decision point returns one of these members so both
    :func:`plan_segments` and the ``DX201`` fusion-explainability rule in
    :mod:`repro.core.analyze` consume the *same* data — the explanation can
    never drift from the behavior.  ``str(reason)`` / ``reason.explain``
    give the operator-facing sentence.
    """

    #: The stream's AU is not ``Placement.DEVICE`` (host stages run on the bus).
    NOT_DEVICE = "not-device"
    #: The AU declares ``stateful=True`` — fused programs must be pure.
    STATEFUL = "stateful"
    #: The AU is itself a fused unit; never re-fuse one.
    FUSED_UNIT = "fused-unit"
    #: The AU's logic owns its own consume loop (SDK-style) — can't chain.
    SDK_STYLE = "sdk-style"
    #: The stream has more than one input subject (``fuse`` combinators etc.).
    MULTI_INPUT = "multi-input"
    #: ``fixed_instances > 1`` — fusing would change scaling semantics.
    FIXED_INSTANCES = "fixed-instances"
    #: The upstream subject has >1 consumer (or none); it must stay on the bus.
    MULTI_SUBSCRIBER = "multi-subscriber"
    #: The upstream subject is ``.tap()``-promised to external subscribers.
    TAPPED = "tapped"
    #: The upstream subject is durable; its log only fills on real publishes.
    DURABLE = "durable"
    #: The consumer replays history (``replay_from``); folding it mid-segment
    #: would re-anchor the replay onto the segment entry's subject.
    REPLAY = "replay"
    #: The keyed consumer re-partitions on its input (different key field, or
    #: a keyed consumer of an unkeyed stage).
    REPARTITION = "repartition"

    @property
    def explain(self) -> str:
        """One operator-facing sentence for this barrier."""
        return _BARRIER_EXPLANATIONS[self]

    def __str__(self) -> str:  # noqa: D105 - delegate to the explanation
        return f"{self.name}: {self.explain}"


_BARRIER_EXPLANATIONS: dict[BarrierReason, str] = {
    BarrierReason.NOT_DEVICE:
        "the stage is not DEVICE-placed, so it runs on the bus",
    BarrierReason.STATEFUL:
        "the stage declares stateful=True and fused programs must be pure",
    BarrierReason.FUSED_UNIT:
        "the stage is already a fused unit and is never re-fused",
    BarrierReason.SDK_STYLE:
        "the stage's logic owns its own consume loop and cannot be chained",
    BarrierReason.MULTI_INPUT:
        "the stage consumes more than one input subject",
    BarrierReason.FIXED_INSTANCES:
        "fixed_instances > 1 — fusing would change scaling semantics",
    BarrierReason.MULTI_SUBSCRIBER:
        "the upstream subject has more than one consumer (or none) and must "
        "stay on the bus",
    BarrierReason.TAPPED:
        "the upstream subject is .tap()-promised to external subscribers",
    BarrierReason.DURABLE:
        "the upstream subject is durable; its append-only log only fills if "
        "publishes hit the bus",
    BarrierReason.REPLAY:
        "the consumer replays history from its own input subject's log",
    BarrierReason.REPARTITION:
        "the keyed consumer re-partitions on its input (key differs from the "
        "upstream's, or the upstream is unkeyed)",
}


def consumer_counts(app: Application) -> dict[str, int]:
    """How many streams + gadgets consume each subject of ``app``."""
    counts: dict[str, int] = {}
    for s in app.streams:
        for i in s.inputs:
            counts[i] = counts.get(i, 0) + 1
    for g in app.gadgets:
        for i in g.inputs:
            counts[i] = counts.get(i, 0) + 1
    return counts


def stream_barrier(spec: StreamSpec,
                   aus: Mapping[str, AnalyticsUnitSpec]) -> BarrierReason | None:
    """Why ``spec`` can never be a fused-segment stage (None = fusible).

    These are properties of the stream/AU alone; :func:`edge_barrier` adds
    the edge-level reasons that depend on the upstream subject.
    """
    au = aus.get(spec.analytics_unit)
    if au is None or au.placement is not Placement.DEVICE:
        return BarrierReason.NOT_DEVICE
    if au.fused_stages:                  # never re-fuse a fused unit
        return BarrierReason.FUSED_UNIT
    if au.stateful:
        return BarrierReason.STATEFUL
    if is_sdk_style(au.logic):           # owns its own loop — can't chain
        return BarrierReason.SDK_STYLE
    if len(spec.inputs) != 1:
        return BarrierReason.MULTI_INPUT
    if spec.fixed_instances not in (None, 1):
        return BarrierReason.FIXED_INSTANCES
    return None


def edge_barrier(upstream: StreamSpec, nxt: StreamSpec,
                 aus: Mapping[str, AnalyticsUnitSpec], *,
                 consumers: Mapping[str, int],
                 taps: Iterable[str] = ()) -> BarrierReason | None:
    """Why ``nxt`` cannot extend a fused segment through ``upstream``.

    Returns None when the edge fuses.  ``consumers`` is
    :func:`consumer_counts` of the application; ``taps`` the promised
    subjects.  Subsumes :func:`stream_barrier` of ``nxt``.
    """
    if upstream.name in taps:
        # promised to external subscribers — must remain a bus subject
        return BarrierReason.TAPPED
    if consumers.get(upstream.name, 0) != 1:
        return BarrierReason.MULTI_SUBSCRIBER
    if upstream.durable:
        # a durable interior stream is a promise just like a tap: its
        # append-only log only fills if publishes hit the bus subject,
        # so it must stay a segment boundary
        return BarrierReason.DURABLE
    reason = stream_barrier(nxt, aus)
    if reason is not None:
        return reason
    if nxt.replay_from is not None:
        # a replaying consumer starts on its OWN input subjects' logs;
        # folding it mid-segment would re-anchor the replay onto the
        # segment entry's subject.  It may still head its own segment
        # (the fused unit inherits the entry's replay_from).
        return BarrierReason.REPLAY
    if nxt.delivery == "keyed" and not (upstream.delivery == "keyed"
                                        and upstream.key == nxt.key):
        # a keyed consumer re-partitions on ITS input.  If the chain is
        # uniformly keyed on the SAME field (the DSL propagates .key_by
        # through stateless stages), the fused unit inherits the entry's
        # key policy and hashes once at entry — equivalent to per-stage
        # hashing as long as interior stages don't rewrite the key
        # field's VALUE (rewriting it while keeping the field in the
        # schema re-partitions mid-chain in the unfused graph; keep such
        # a stage out of the device chain or .tap() it).  A different
        # key field (or a keyed consumer of an unkeyed stage) is a
        # genuine re-partition point: the interior stream must stay a
        # bus subject (segment barrier).  Pairwise same-key induction
        # keeps every fused segment uniformly keyed back to its entry.
        return BarrierReason.REPARTITION
    return None


def _fusible(spec: StreamSpec, aus: Mapping[str, AnalyticsUnitSpec]) -> bool:
    return stream_barrier(spec, aus) is None


def plan_segments(app: Application,
                  taps: Iterable[str] = ()) -> list[list[StreamSpec]]:
    """Maximal linear DEVICE segments, in topological order of their entries.

    Every returned segment has length >= 2 (a single DEVICE stream gains
    nothing from fusion — it already is one microservice).
    """
    taps = set(taps)
    aus = {a.name: a for a in app.analytics_units}
    streams = {s.name: s for s in app.streams}
    consumers = consumer_counts(app)

    def extendable(upstream: StreamSpec) -> StreamSpec | None:
        """The unique fusible successor of ``upstream``, or None (barrier)."""
        nxt = next((s for s in app.streams if upstream.name in s.inputs), None)
        if nxt is None:
            return None  # consumed only by gadgets / external subscribers
        if edge_barrier(upstream, nxt, aus,
                        consumers=consumers, taps=taps) is not None:
            return None
        return nxt

    segments: list[list[StreamSpec]] = []
    in_segment: set[str] = set()
    for spec in app.streams:  # declaration order is topological per validate()
        if spec.name in in_segment or not _fusible(spec, aus):
            continue
        # head check: the producer of our input must not absorb us
        prev = streams.get(spec.inputs[0])
        if prev is not None and _fusible(prev, aus) \
                and extendable(prev) is spec:
            continue  # interior of a segment headed earlier
        segment = [spec]
        while True:
            nxt = extendable(segment[-1])
            if nxt is None:
                break
            segment.append(nxt)
        if len(segment) >= 2:
            segments.append(segment)
            in_segment.update(s.name for s in segment)
    return segments


# ---------------------------------------------------------------------------
# Device / host chain execution
# ---------------------------------------------------------------------------

def _to_device(payload: Mapping[str, Any]) -> dict:
    """Payload -> jax arrays.  Raises on non-numeric fields (caller falls
    back to the host chain)."""
    import jax.numpy as jnp
    out = {}
    for k, v in payload.items():
        if isinstance(v, (str, bytes, dict, list, tuple)):
            raise TypeError(f"field {k!r} ({type(v).__name__}) is not "
                            f"device-representable")
        out[k] = jnp.asarray(v)
    return out


def _from_device(payload: Mapping[str, Any],
                 like: Mapping[str, Any]) -> dict:
    """Device arrays -> host values, mirroring what the same stage fns
    produce on numpy inputs (the host/unfused path is ground truth, and the
    two must stay interchangeable):

    * 0-d results of a field that entered as a python scalar -> python
      scalar (pass-through/arithmetic identity);
    * any other 0-d result (reductions, new fields) -> numpy scalar, exactly
      like a numpy reduction — NOT ``.item()``, which would let the jitted
      path accept payloads (e.g. against a ``FieldSpec("float")``) that the
      host path and per-hop bus execution reject;
    * everything else -> ndarray.
    """
    out = {}
    for k, v in payload.items():
        arr = np.asarray(v)
        if arr.ndim == 0:
            src = like.get(k)
            if src is not None and not isinstance(src, (np.ndarray, np.generic)):
                out[k] = arr.item()
            else:
                out[k] = arr[()]
        else:
            out[k] = arr
    return out


def _round_up_pow2(n: int) -> int:
    """Canonical (power-of-two) batch size for a burst of ``n`` messages.

    The jitted batch program retraces per input shape; rounding every burst
    up to the next power of two bounds the set of compiled batch shapes to
    log2(max_batch) instead of one per distinct backlog depth."""
    return 1 << max(0, n - 1).bit_length()


class ResidentArray(np.ndarray):
    """A host ndarray that remembers the device burst it was unstacked from.

    Fused segments whose exit feeds ANOTHER fused segment's entry emit
    their array fields as ResidentArrays: to every host-side consumer
    (schema validation, taps, wire transport) this is a plain numpy array,
    but it additionally holds the stacked device array it is row
    ``_datax_row`` of (``_datax_dev``).  When the downstream fused unit
    stacks a burst whose rows are exactly that still-resident device burst,
    :func:`_to_device_batched` hands the device array straight back to the
    next program — the linked hop pays zero host->device transfer
    (``resident_links`` in sidecar metrics).
    """

    _datax_dev: Any = None
    _datax_row: int = -1

    def __array_finalize__(self, obj):
        # Residency is NEVER inherited by views, slices, or copies: a
        # derived array is not the row the device burst holds, so it must
        # not claim the link.  wrap() is the only residency source.
        self._datax_dev = None
        self._datax_row = -1

    @classmethod
    def wrap(cls, row: np.ndarray, dev: Any, index: int) -> "ResidentArray":
        """Tag host ``row`` as row ``index`` of device array ``dev``."""
        out = np.asarray(row).view(cls)
        out._datax_dev = dev
        out._datax_row = index
        return out


def _resident_burst(rows: Sequence[Any], pad_to: int):
    """The shared device array behind a burst of ResidentArray rows, or None.

    Reuse demands an INTACT burst: every row resident, all from the same
    device array, indices exactly 0..N-1 (a filtered or reordered burst
    skips indices), full-row shapes, and the producer's padded batch equal
    to the consumer's ``pad_to`` (vmap rows are independent, so the
    producer's pad rows — repeats of its last input — are computed and
    discarded exactly like pad rows the consumer would have stacked)."""
    first = rows[0]
    if not isinstance(first, ResidentArray) or first._datax_dev is None:
        return None
    dev = first._datax_dev
    if getattr(dev, "shape", (0,))[0] != pad_to:
        return None
    for i, r in enumerate(rows):
        if (not isinstance(r, ResidentArray) or r._datax_dev is not dev
                or r._datax_row != i or r.shape != dev.shape[1:]):
            return None
    return dev


def _to_device_batched(payloads: Sequence[Mapping[str, Any]],
                       pad_to: int, stats: dict | None = None) -> dict:
    """Stack N payloads field-wise into one leading-batch-dim device payload.

    Raises TypeError on heterogeneous field sets, non-numeric fields, or
    ragged/mixed shapes-dtypes across the burst — the caller degrades that
    burst to per-message execution, bit-identical to the host chain.  Tails
    shorter than ``pad_to`` are padded by repeating the last row (the pad
    rows' outputs are discarded) so batch shapes stay canonical.

    Fields whose rows form an intact :class:`ResidentArray` burst skip the
    stack + transfer entirely and reuse the upstream device array
    (counted in ``stats['resident_links']`` when a stats dict is given)."""
    import jax.numpy as jnp
    keys = payloads[0].keys()
    for p in payloads[1:]:
        if p.keys() != keys:
            raise TypeError("burst payloads carry different field sets")
    out = {}
    for k in keys:
        resident = _resident_burst([p[k] for p in payloads], pad_to)
        if resident is not None:
            out[k] = resident
            if stats is not None:
                stats["resident_links"] += 1
            continue
        rows = []
        for p in payloads:
            v = p[k]
            if isinstance(v, (str, bytes, dict, list, tuple)) or v is None:
                raise TypeError(f"field {k!r} ({type(v).__name__}) is not "
                                f"device-representable")
            arr = np.asarray(v)
            if arr.dtype == object:
                raise TypeError(f"field {k!r} is not device-representable")
            rows.append(arr)
        first = rows[0]
        if any(r.shape != first.shape or r.dtype != first.dtype
               for r in rows[1:]):
            raise TypeError(f"field {k!r}: ragged shapes/dtypes across burst")
        if len(rows) < pad_to:
            rows.extend(rows[-1:] * (pad_to - len(rows)))
        out[k] = jnp.asarray(np.stack(rows))
    return out


def _from_device_batched(stacked: Mapping[str, Any],
                         likes: Sequence[Mapping[str, Any]],
                         resident: bool = False) -> list[dict]:
    """Stacked device results -> one host payload per (unpadded) message.

    One device->host transfer per FIELD for the whole burst — that single
    materialization is where batching beats per-message ``_from_device`` —
    then each row follows the exact scalar-typing rules of
    :func:`_from_device` against its own entry payload.

    With ``resident=True`` (segments feeding another fused segment) array
    rows come back as :class:`ResidentArray`, pinning the stacked device
    result so the downstream unit can reuse it without re-uploading."""
    host = {k: np.asarray(v) for k, v in stacked.items()}
    outs = []
    for i, like in enumerate(likes):
        p = {}
        for k, arr in host.items():
            row = arr[i]
            if row.ndim == 0:
                src = like.get(k)
                if src is not None and not isinstance(src, (np.ndarray,
                                                            np.generic)):
                    p[k] = row.item()
                else:
                    p[k] = row[()]
            elif resident:
                # the copy below intentionally does NOT apply: residency
                # trades keeping the device burst alive for a free re-entry
                # on the linked hop
                p[k] = ResidentArray.wrap(np.array(row), stacked[k], i)
            else:
                # copy out of the stacked block: a view would keep the whole
                # pad_to-sized burst alive for as long as ANY downstream
                # consumer holds one message of it
                p[k] = np.array(row)
        outs.append(p)
    return outs


def make_fused_logic(stages: Sequence[FusedStage],
                     entry_schema: StreamSchema | None,
                     max_batch: int | None = None,
                     resident: bool = False) -> Callable:
    """Factory for the fused AU: chain every stage in one instance.

    The returned factory honours the normal AU contract
    (``factory(ctx) -> process(stream, payload)``) so the Executor runs a
    fused unit exactly like any other microservice; additionally ``process``
    exposes the batched-execution surface the Executor's drain-a-burst mode
    keys on — ``process_batch`` (whole mailbox burst -> one vmapped program
    call; mesh-sharded when :func:`fusion_mesh` is live and the padded
    burst divides it), ``default_max_batch``, ``current_max_batch`` (the
    autotuned ceiling, present only when the stream declared no
    ``max_batch`` of its own) and a ``stats`` counter dict
    (``device_fallbacks`` / ``batched_bursts`` / ``batched_msgs`` /
    ``sharded_bursts`` / ``resident_links`` / ``mesh_devices`` /
    ``max_batch_current``).  ``resident=True`` marks a segment whose exit
    feeds another fused segment: its array outputs stay device-resident
    (:class:`ResidentArray`) for the linked hop.
    """

    def fused_factory(ctx):
        procs = []
        for st in stages:
            sctx = LogicContext(dict(st.config), db=ctx.db,
                                instance_id=ctx.instance_id,
                                stop_event=getattr(ctx, "_stop", None))
            procs.append(st.factory(sctx))

        def host_chain(i: int, stream: str, payload: dict) -> list:
            if i == len(procs):
                return [payload]
            out = procs[i](stream, payload)
            if out is None:
                return []
            results = []
            for p in (out if isinstance(out, list) else [out]):
                results.extend(host_chain(i + 1, stages[i].stream_name, p))
            return results

        program = batched_program = mesh = None
        sprog = {"fn": None}  # sharded program; retired on lowering failure
        if jax_available() and _want_jit() \
                and all(st.pure_fn is not None for st in stages):
            from ..kernels.ops import jit_chain, jit_chain_batched
            chain = [(st.kind, st.pure_fn) for st in stages]
            program = jit_chain(chain)
            batched_program = jit_chain_batched(chain)
            mesh = fusion_mesh()
            if mesh is not None:
                from ..distributed.sharding import burst_spec
                from ..kernels.ops import jit_chain_sharded
                hints = (entry_schema.sharding_hints()
                         if entry_schema is not None else {})
                specs = {}
                if entry_schema is not None:
                    for fname, f in entry_schema.fields.items():
                        if f.kind == "device" and f.shape is not None \
                                and -1 not in f.shape:
                            # build against a divisible batch: the runtime
                            # gate below only routes divisible bursts here
                            specs[fname] = burst_spec(
                                mesh, mesh.size, f.shape, hints.get(fname))
                sprog["fn"] = jit_chain_sharded(chain, mesh, specs)
        ndev = mesh.size if mesh is not None else 1
        mode = {"device": program is not None}
        # device_fallbacks counts MESSAGES that ran on the host while the
        # device program stayed live (payload-local problems);
        # unstackable_bursts counts bursts that degraded to per-message
        # dispatch (ragged/mixed shapes) — those messages may still run on
        # the device one at a time, so they are not fallbacks.
        tune = {"cur": max_batch or DEFAULT_MAX_BATCH, "streak": 0,
                "slow": 0,
                "auto": max_batch is None and program is not None}
        stats = {"device_fallbacks": 0, "unstackable_bursts": 0,
                 "batched_bursts": 0, "batched_msgs": 0,
                 "sharded_bursts": 0, "resident_links": 0,
                 "mesh_devices": ndev, "max_batch_current": tune["cur"]}

        def run_device(payload: dict) -> dict | None:
            dev, keep = program(_to_device(payload))
            if not bool(keep):
                return None
            return _from_device(dev, payload)

        def host_one(stream: str, payload: dict):
            out = host_chain(0, stream, payload)
            if not out:
                return None
            return out if len(out) > 1 else out[0]

        def process(stream: str, payload: dict):
            if mode["device"]:
                try:
                    dev = _to_device(payload)
                except Exception:
                    # conversion failures are ALWAYS payload problems
                    # (non-numeric field -> TypeError, oversized python int
                    # -> OverflowError, ...), never program problems: fall
                    # back for THIS message only and keep the device program
                    # live for the rest of the stream
                    stats["device_fallbacks"] += 1
                else:
                    try:
                        out, keep = program(dev)
                        return _from_device(out, payload) if bool(keep) \
                            else None
                    except Exception:
                        # genuine trace failure (impure/untraceable stage):
                        # permanently drop to the host-composed chain (still
                        # zero bus hops)
                        mode["device"] = False
            return host_one(stream, payload)

        def autotune(burst: int, drain_s: float) -> None:
            # occupancy feedback: a burst that fills the current ceiling
            # means the mailbox still had messages left behind; a streak of
            # them means the ceiling — not the arrival rate — is the
            # bottleneck, so double it (pad shapes stay powers of two).
            # Latency feedback runs the other way: a streak of over-budget
            # bursts means the ceiling is past the device's sweet spot and
            # every message is paying the whole burst's wall time — halve it.
            if not tune["auto"]:
                return
            if drain_s > AUTOTUNE_BUDGET_S:
                tune["streak"] = 0  # never grow through a latency breach
                tune["slow"] += 1
                if tune["slow"] >= AUTOTUNE_DOWN_STREAK and tune["cur"] > 1:
                    tune["cur"] = max(1, tune["cur"] // 2)
                    tune["slow"] = 0
                    stats["max_batch_current"] = tune["cur"]
                return
            tune["slow"] = 0
            if burst >= tune["cur"]:
                tune["streak"] += 1
                if tune["streak"] >= AUTOTUNE_STREAK \
                        and tune["cur"] < AUTOTUNE_MAX_BATCH:
                    tune["cur"] = min(tune["cur"] * 2, AUTOTUNE_MAX_BATCH)
                    tune["streak"] = 0
                    stats["max_batch_current"] = tune["cur"]
            else:
                tune["streak"] = 0

        def process_batch(stream: str, payloads: Sequence[dict]) -> list:
            """One vmapped device call for a whole mailbox burst; returns a
            per-message result list (None = filtered), order preserved.
            Pads that divide the mesh run the SPMD-sharded program; bursts
            the device cannot stack (ragged/mixed shapes, non-numeric
            fields) degrade to the per-message path — bit-identical to the
            host chain."""
            if mode["device"] and batched_program is not None \
                    and len(payloads) > 1:
                pad_to = _round_up_pow2(len(payloads))
                t0 = time.monotonic()
                try:
                    dev = _to_device_batched(payloads, pad_to, stats)
                except Exception:
                    # conversion = payload problem (ragged shapes, mixed
                    # dtypes, non-numeric or unconvertible values): burst-
                    # level degrade only — the per-message path below still
                    # tries the device for each message, and counts a
                    # device_fallback only for the ones that truly drop to
                    # the host chain
                    stats["unstackable_bursts"] += 1
                else:
                    sharded = sprog["fn"] if pad_to % ndev == 0 else None
                    try:
                        if sharded is not None:
                            try:
                                out, keep = sharded(dev)
                            except Exception:
                                # sharding-specific lowering failure: retire
                                # the sharded program for this unit; the
                                # single-device batched program stays live
                                sprog["fn"] = sharded = None
                        if sharded is None:
                            out, keep = batched_program(dev)
                        keep = np.asarray(keep)
                    except Exception:
                        mode["device"] = False
                    else:
                        stats["batched_bursts"] += 1
                        stats["batched_msgs"] += len(payloads)
                        if sharded is not None:
                            stats["sharded_bursts"] += 1
                        autotune(len(payloads), time.monotonic() - t0)
                        host = _from_device_batched(out, payloads,
                                                    resident=resident)
                        return [host[i] if keep[i] else None
                                for i in range(len(payloads))]
            # per-message fallback: a poison message here must not destroy
            # its already-processed predecessors — hand the successful
            # prefix to the Executor so it is emitted before the crash and
            # only the poison + unprocessed tail count as lost
            results: list = []
            for p in payloads:
                try:
                    results.append(process(stream, p))
                except Exception as e:
                    raise BatchInterrupted(results) from e
            return results

        process.process_batch = process_batch
        process.default_max_batch = max_batch or DEFAULT_MAX_BATCH
        process.stats = stats
        if tune["auto"]:
            # the Executor re-reads this each pump iteration, so a doubled
            # ceiling takes effect on the very next mailbox drain
            process.current_max_batch = lambda: tune["cur"]

        if program is not None and entry_schema is not None:
            zeros = entry_schema.zero_payload()
            if zeros is not None:
                canonical = _round_up_pow2(process.default_max_batch)

                def warmup():
                    # compile before the first real message; the Executor
                    # calls this ahead of the pump loop and keeps the cost
                    # out of the latency EWMA.  The batched program warms at
                    # the canonical (full) burst size — the steady-state
                    # shape under backlog — and the sharded lowering warms
                    # alongside it when the mesh divides that shape.
                    run_device(zeros)
                    if batched_program is not None and canonical > 1:
                        dev = _to_device_batched([zeros, zeros], canonical)
                        batched_program(dev)
                        if sprog["fn"] is not None and canonical % ndev == 0:
                            try:
                                sprog["fn"](dev)
                            except Exception:
                                sprog["fn"] = None
                process.warmup = warmup
        return process

    return fused_factory


# ---------------------------------------------------------------------------
# The pass
# ---------------------------------------------------------------------------

def _stage_kind(au: AnalyticsUnitSpec) -> str:
    return au.combinator if au.combinator in ("map", "filter") else "au"


def fuse_application(app: Application, *,
                     taps: Iterable[str] = ()) -> Application:
    """Collapse every DEVICE segment of ``app`` into one fused AU + stream.

    Pure: returns a new Application (or ``app`` unchanged when nothing fuses).
    """
    segments = plan_segments(app, taps)
    if not segments:
        return app

    aus = {a.name: a for a in app.analytics_units}
    producer_schema: dict[str, StreamSchema] = {}
    for sensor in app.sensors:
        drv = next((d for d in app.drivers if d.name == sensor.driver), None)
        if drv is not None:
            producer_schema[sensor.name] = drv.output_schema
    for s in app.streams:
        au = aus.get(s.analytics_unit)
        if au is not None:
            producer_schema[s.name] = au.output_schema

    fused_streams: list[StreamSpec] = []
    fused_aus: list[AnalyticsUnitSpec] = []
    folded: set[str] = set()
    au_names = set(aus)
    # exits that feed ANOTHER fused segment's entry keep their arrays
    # device-resident: the linked hop's bus message carries ResidentArray
    # rows the downstream unit re-enters without a host->device transfer
    linked_exits = ({seg[-1].name for seg in segments}
                    & {seg[0].inputs[0] for seg in segments})
    for segment in segments:
        entry, exit_ = segment[0], segment[-1]
        stage_aus = [aus[s.analytics_unit] for s in segment]
        stages = tuple(
            FusedStage(au_name=au.name, stream_name=s.name, factory=au.logic,
                       config=au.config_schema.validate(dict(s.config)),
                       kind=_stage_kind(au), pure_fn=au.pure_fn)
            for s, au in zip(segment, stage_aus))
        name = f"{exit_.name}.fused"
        while name in au_names:
            name += "+"
        au_names.add(name)
        entry_schema = producer_schema.get(entry.inputs[0])
        # batching envelope: the fused unit consumes the ENTRY subject, so a
        # max_batch declared on any folded stage carries over.  When several
        # stages declare one, the stage closest to the segment EXIT wins —
        # the last word in chain order, which is what lets a trailing
        # .scaled(max_batch=1) force per-message dispatch over an earlier
        # stage's burst setting.
        declared_batch = [s.max_batch for s in segment
                          if s.max_batch is not None]
        seg_max_batch = declared_batch[-1] if declared_batch else None
        # the segment's envelope: never exceed ANY stage's declared ceiling;
        # a contradictory pair (one stage's floor above another's ceiling)
        # clamps the floor down rather than violating the ceiling
        hi = max(1, min(au.max_instances for au in stage_aus))
        lo = min(max(au.min_instances for au in stage_aus), hi)
        fused_aus.append(AnalyticsUnitSpec(
            name=name, logic=make_fused_logic(stages, entry_schema,
                                              max_batch=seg_max_batch,
                                              resident=exit_.name
                                              in linked_exits),
            input_schemas=tuple(stage_aus[0].input_schemas),
            output_schema=stage_aus[-1].output_schema,
            placement=Placement.DEVICE,
            min_instances=lo, max_instances=hi,
            fused_stages=tuple(st.au_name for st in stages)))
        # delivery mode follows the ENTRY stream: it governs how instances
        # consume the segment's input subject (interior hops have no bus
        # delivery at all).  Under "group" every fused-unit instance is one
        # member of the exit-named queue group, so a scaled fused segment is
        # a worker pool exactly like a scaled host stream; a keyed entry's
        # key policy is inherited wholesale (each key sticks to one fused
        # instance).  Mid-chain keyed streams never get here — they are
        # segment barriers in plan_segments.
        # durability follows the edges that remain on the bus: the ENTRY's
        # replay_from (the fused unit consumes the entry's input subjects)
        # and the EXIT's durable log (the fused stream publishes under the
        # exit's name).  Interior durable streams never get here — they are
        # segment barriers in plan_segments.
        fused_streams.append(StreamSpec(
            name=exit_.name, analytics_unit=name, inputs=tuple(entry.inputs),
            fixed_instances=1 if any(s.fixed_instances == 1 for s in segment)
            else None,
            delivery=entry.delivery, key=entry.key, steal=entry.steal,
            max_batch=seg_max_batch,
            durable=exit_.durable, retention=exit_.retention,
            replay_from=entry.replay_from))
        folded.update(s.name for s in segment)

    streams = [s for s in app.streams if s.name not in folded] + fused_streams
    referenced = {s.analytics_unit for s in streams}
    units = [a for a in app.analytics_units
             if a.name in referenced or not a.combinator] + fused_aus
    return dataclasses.replace(app, streams=streams, analytics_units=units)
