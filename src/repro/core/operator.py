"""DataX Operator — registry + reconciler with coherence enforcement (paper §4).

The Operator is the paper's core mechanism: it owns every entity's lifecycle
and "takes necessary actions to ensure that all DataX applications are in a
coherent state at all times", protecting the system from user actions that
would make it unrecoverable.  Faithfully implemented rules:

* **register driver/AU/actuator** — unique names, validated specs.
* **upgrade** — only if the new config schema *accepts* every running
  instance's config; otherwise the user may supply a converter script, and the
  upgrade is accepted only if the converter succeeds for ALL running instances
  (§4, verbatim behaviour).  Accepted upgrades cascade: running instances are
  restarted with the new logic + (converted) configs.
* **delete driver/AU/actuator** — refused while any sensor/stream/gadget uses
  it ("refuse the operation if there is already a running instance").
* **register sensor** — requires (a) driver installed, (b) config compatible;
  the Operator "will also maintain the driver's running instance ... as long
  as the sensor is registered"; the sensor's output stream gets the sensor's
  name.  Node affinity (the paper's USB-attached case) pins the instance.
* **create stream** — AU available + config compatible + all input streams
  registered; instance count auto-scaled unless the user fixed it.
* **delete sensor/stream** — refused while the stream feeds other streams or
  gadgets ("ensures that they are not input to produce other streams").
* **reconcile loop** — restarts crashed instances (reliable operation),
  applies autoscale decisions, flags stragglers (latency ≫ peer median) and
  replaces them.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Callable, Mapping

from .bus import MessageBus
from .delivery import Group, Keyed, ReplayFrom, resolve_replay
from .durable import DurableError, Retention, resolve_replay_from
from .entities import (ActuatorSpec, AnalyticsUnitSpec, DatabaseSpec,
                       DriverSpec, GadgetSpec, Placement, SensorSpec,
                       StreamSpec)
from .serverless import AutoScaler, Executor, InstanceHandle, ScalePolicy
from .state import Database, StateStore


class CoherenceError(RuntimeError):
    """User action refused: it would leave the platform incoherent (§4)."""


class OperatorError(RuntimeError):
    pass


class Operator:
    """The control plane.  One per DataX deployment."""

    def __init__(self, *, bus: MessageBus | None = None,
                 state_root: str | None = None,
                 scale_policy: ScalePolicy | None = None,
                 straggler_factor: float = 4.0,
                 reconcile_interval_s: float = 0.2):
        self.bus = bus or MessageBus()
        self.store = StateStore(root=state_root)
        self._state_root = state_root
        self.executor = Executor(self.bus)
        self.autoscaler = AutoScaler(scale_policy)
        self.straggler_factor = straggler_factor
        self._reconcile_interval_s = reconcile_interval_s

        self._lock = threading.RLock()
        # code entities
        self._drivers: dict[str, DriverSpec] = {}
        self._aus: dict[str, AnalyticsUnitSpec] = {}
        self._actuators: dict[str, ActuatorSpec] = {}
        # instance entities (desired state)
        self._sensors: dict[str, SensorSpec] = {}
        self._streams: dict[str, StreamSpec] = {}
        self._gadgets: dict[str, GadgetSpec] = {}
        self._databases: dict[str, DatabaseSpec] = {}
        # resolved configs for running entities (post schema validation)
        self._resolved: dict[str, dict] = {}
        # events observed by tests/ops tooling
        self.events: list[tuple[float, str, str]] = []
        # datax-check diagnostic summaries recorded at deploy, per app name,
        # plus a node -> entries view pushed onto instance sidecars at spawn
        self._diagnostics: dict[str, list[dict]] = {}
        self._diag_by_node: dict[str, list[dict]] = {}
        self._pending_sensors: list[str] = []
        self._reconciler: threading.Thread | None = None
        self._stop = threading.Event()
        self._bus_server = None  # transport.BusServer once serve() is called

    # ------------------------------------------------------------------ util
    def _event(self, kind: str, detail: str) -> None:
        with self._lock:
            self.events.append((time.monotonic(), kind, detail))

    def _stream_names(self) -> set[str]:
        with self._lock:
            return set(self._sensors) | set(self._streams)

    def _durable_root(self, subject: str) -> str | None:
        """On-disk home for a subject's durable log (None = memory-only —
        history then lives as long as the deployment, like memkv state)."""
        if not self._state_root:
            return None
        return os.path.join(self._state_root, "durable", subject)

    def _make_durable(self, subject: str,
                      retention: Mapping[str, Any] | None) -> None:
        try:
            Retention.of(dict(retention) if retention else None)
        except DurableError as e:
            raise OperatorError(f"stream {subject!r}: {e}") from None
        self.bus.make_durable(subject, retention=dict(retention)
                              if retention else None,
                              root=self._durable_root(subject))

    # =====================================================================
    # Code entities: drivers, AUs, actuators
    # =====================================================================

    def register_driver(self, spec: DriverSpec) -> None:
        """Register a driver (sensor logic) spec; name must be new."""
        with self._lock:
            if spec.name in self._drivers:
                raise OperatorError(f"driver {spec.name!r} already registered")
            self._drivers[spec.name] = spec
        self._event("register", f"driver/{spec.name}@v{spec.version}")

    def register_analytics_unit(self, spec: AnalyticsUnitSpec) -> None:
        """Register an analytics-unit spec; name must be new."""
        with self._lock:
            if spec.name in self._aus:
                raise OperatorError(f"analytics unit {spec.name!r} already registered")
            self._aus[spec.name] = spec
        self._event("register", f"au/{spec.name}@v{spec.version}")

    def register_actuator(self, spec: ActuatorSpec) -> None:
        """Register an actuator (gadget logic) spec; name must be new."""
        with self._lock:
            if spec.name in self._actuators:
                raise OperatorError(f"actuator {spec.name!r} already registered")
            self._actuators[spec.name] = spec
        self._event("register", f"actuator/{spec.name}@v{spec.version}")

    # -- upgrades (§4: cascade + compatibility or converter) -----------------
    def upgrade_analytics_unit(self, spec: AnalyticsUnitSpec,
                               converter: Callable[[dict], dict] | None = None) -> None:
        """Upgrade an AU to a higher version and cascade to every running
        stream using it; an incompatible config schema needs a
        ``converter(old_cfg) -> new_cfg`` that succeeds for all users
        (paper §4)."""
        self._upgrade_code_entity("au", self._aus, spec, converter,
                                  users=lambda: [s for s in self._streams.values()
                                                 if s.analytics_unit == spec.name])

    def upgrade_driver(self, spec: DriverSpec,
                       converter: Callable[[dict], dict] | None = None) -> None:
        """Upgrade a driver and cascade to its sensors; see
        :meth:`upgrade_analytics_unit` for converter semantics."""
        self._upgrade_code_entity("driver", self._drivers, spec, converter,
                                  users=lambda: [s for s in self._sensors.values()
                                                 if s.driver == spec.name])

    def upgrade_actuator(self, spec: ActuatorSpec,
                         converter: Callable[[dict], dict] | None = None) -> None:
        """Upgrade an actuator and cascade to its gadgets; see
        :meth:`upgrade_analytics_unit` for converter semantics."""
        self._upgrade_code_entity("actuator", self._actuators, spec, converter,
                                  users=lambda: [g for g in self._gadgets.values()
                                                 if g.actuator == spec.name])

    def _upgrade_code_entity(self, kind: str, registry: dict, spec,
                             converter, users: Callable[[], list]) -> None:
        with self._lock:
            if spec.name not in registry:
                raise OperatorError(f"{kind} {spec.name!r} not registered")
            old = registry[spec.name]
            if spec.version <= old.version:
                raise OperatorError(
                    f"{kind} {spec.name!r}: version must increase "
                    f"({old.version} -> {spec.version})")
            using = users()
            new_configs: dict[str, dict] = {}
            for user in using:
                cfg = dict(user.config)
                if converter is not None:
                    # §4: accept only if the converter executes successfully
                    # for ALL running instances.
                    try:
                        cfg = converter(cfg)
                    except Exception as e:
                        raise CoherenceError(
                            f"upgrade of {kind} {spec.name!r} rejected: converter "
                            f"failed for {user.name!r}: {e}") from None
                try:
                    new_configs[user.name] = spec.config_schema.validate(cfg)
                except Exception as e:
                    raise CoherenceError(
                        f"upgrade of {kind} {spec.name!r} rejected: config of "
                        f"{user.name!r} incompatible with new schema: {e}") from None
            if converter is None and using and \
                    not spec.config_schema.accepts_configs_of(old.config_schema):
                raise CoherenceError(
                    f"upgrade of {kind} {spec.name!r} rejected: new config schema "
                    f"is not compatible with the running instances' schema")
            registry[spec.name] = spec
            for name, cfg in new_configs.items():
                self._resolved[name] = cfg
        # cascade: restart running instances with new logic/config (§4)
        for user in using:
            self._restart_owner(user.name)
        self._event("upgrade", f"{kind}/{spec.name}@v{spec.version} "
                               f"(cascaded to {len(using)} instances)")

    # -- deletion (§4: refuse while in use) -----------------------------------
    def delete_driver(self, name: str) -> None:
        """Remove a driver; refused (CoherenceError) while sensors use it."""
        with self._lock:
            if name not in self._drivers:
                raise OperatorError(f"driver {name!r} not registered")
            users = [s.name for s in self._sensors.values() if s.driver == name]
            if users:
                raise CoherenceError(
                    f"cannot delete driver {name!r}: in use by sensors {users}")
            del self._drivers[name]
        self._event("delete", f"driver/{name}")

    def delete_analytics_unit(self, name: str) -> None:
        """Remove an AU; refused (CoherenceError) while streams use it."""
        with self._lock:
            if name not in self._aus:
                raise OperatorError(f"analytics unit {name!r} not registered")
            users = [s.name for s in self._streams.values()
                     if s.analytics_unit == name]
            if users:
                raise CoherenceError(
                    f"cannot delete analytics unit {name!r}: in use by streams {users}")
            del self._aus[name]
        self._event("delete", f"au/{name}")

    def delete_actuator(self, name: str) -> None:
        """Remove an actuator; refused (CoherenceError) while gadgets use it."""
        with self._lock:
            if name not in self._actuators:
                raise OperatorError(f"actuator {name!r} not registered")
            users = [g.name for g in self._gadgets.values() if g.actuator == name]
            if users:
                raise CoherenceError(
                    f"cannot delete actuator {name!r}: in use by gadgets {users}")
            del self._actuators[name]
        self._event("delete", f"actuator/{name}")

    # =====================================================================
    # Instance entities: sensors, streams, gadgets, databases
    # =====================================================================

    def register_sensor(self, spec: SensorSpec, *, start: bool = True) -> None:
        """``start=False`` defers the driver instance until
        :meth:`start_pending_sensors` — used by Application.deploy so finite
        sources cannot emit before downstream AUs have subscribed (streams
        are lossy; there is no replay)."""
        with self._lock:
            if spec.name in self._stream_names():
                raise OperatorError(f"name {spec.name!r} already a stream/sensor")
            if spec.driver not in self._drivers:
                raise CoherenceError(
                    f"sensor {spec.name!r}: driver {spec.driver!r} is not installed")
            driver = self._drivers[spec.driver]
            resolved = driver.config_schema.validate(spec.config)  # (b) in §4
            self._sensors[spec.name] = spec
            self._resolved[spec.name] = resolved
        # a registered sensor always generates a stream with the sensor's name
        self.bus.register_subject(spec.name, driver.output_schema)
        if spec.durable:
            self._make_durable(spec.name, spec.retention)
        if start:
            self._spawn_driver(spec, driver, resolved)
        else:
            with self._lock:
                self._pending_sensors.append(spec.name)
        self._event("register", f"sensor/{spec.name} (driver={spec.driver})")

    def start_pending_sensors(self) -> None:
        """Spawn the driver instances of sensors registered with
        ``start=False`` (deferred so a topology can be staged first)."""
        with self._lock:
            pending, self._pending_sensors = self._pending_sensors, []
        for name in pending:
            with self._lock:
                spec = self._sensors.get(name)
                if spec is None:
                    continue
                driver = self._drivers[spec.driver]
                resolved = self._resolved[name]
            self._spawn_driver(spec, driver, resolved)

    def _spawn_driver(self, spec: SensorSpec, driver: DriverSpec,
                      resolved: Mapping[str, Any]) -> InstanceHandle:
        return self.executor.start_instance(
            entity_kind="driver", entity_name=driver.name, owner=spec.name,
            logic=driver.logic, config=dict(resolved), inputs=(),
            output=spec.name, db=self._db_for(resolved),
            node=driver.node_affinity)

    def create_stream(self, spec: StreamSpec) -> None:
        """Create a stream: validate coherence (AU exists, inputs
        registered, delivery/key/replay settings consistent), register its
        bus subject, and start its instances."""
        with self._lock:
            if spec.name in self._stream_names():
                raise OperatorError(f"name {spec.name!r} already a stream/sensor")
            if spec.analytics_unit not in self._aus:
                raise CoherenceError(
                    f"stream {spec.name!r}: analytics unit "
                    f"{spec.analytics_unit!r} is not available")
            au = self._aus[spec.analytics_unit]
            if spec.delivery not in ("group", "keyed", "broadcast"):
                raise OperatorError(
                    f"stream {spec.name!r}: delivery must be 'group', "
                    f"'keyed' or 'broadcast', got {spec.delivery!r}")
            if spec.delivery == "keyed" and not spec.key:
                raise OperatorError(
                    f"stream {spec.name!r}: keyed delivery needs key= "
                    f"(the payload field to hash)")
            if spec.key and spec.delivery != "keyed":
                raise OperatorError(
                    f"stream {spec.name!r}: key={spec.key!r} requires "
                    f"delivery='keyed', got {spec.delivery!r}")
            if spec.max_batch is not None and spec.max_batch < 1:
                raise OperatorError(
                    f"stream {spec.name!r}: max_batch must be >= 1, "
                    f"got {spec.max_batch}")
            if spec.retention is not None and not spec.durable:
                raise OperatorError(
                    f"stream {spec.name!r}: retention= requires durable=True")
            if spec.steal and spec.delivery == "broadcast":
                raise OperatorError(
                    f"stream {spec.name!r}: steal=True needs a queue group "
                    f"to steal from; broadcast instances each see every "
                    f"message already")
            missing = [s for s in spec.inputs if s not in self._stream_names()]
            if missing:
                raise CoherenceError(
                    f"stream {spec.name!r}: input streams not registered: {missing}")
            if spec.replay_from is not None:
                # replay reads history from the INPUT subjects' logs — every
                # input must be durable, or the history simply does not exist
                non_durable = [s for s in spec.inputs
                               if self.bus.durable_log(s) is None]
                if non_durable:
                    raise CoherenceError(
                        f"stream {spec.name!r}: replay_from="
                        f"{spec.replay_from!r} requires durable inputs, but "
                        f"{non_durable} are fire-and-forget (declare them "
                        f"with durable=True)")
            if spec.delivery == "keyed":
                # the hashed field must be a declared field of every typed
                # input — a missing key would silently pile every message
                # onto one partition
                for inp in spec.inputs:
                    schema = self.bus.schema_of(inp)
                    if schema.fields and spec.key not in schema.fields:
                        raise CoherenceError(
                            f"stream {spec.name!r}: key field {spec.key!r} "
                            f"is not in the schema of input {inp!r}")
            resolved = au.config_schema.validate(spec.config)
            # input schema compatibility: each declared input schema must accept
            # the corresponding registered stream's schema
            for i, schema in enumerate(au.input_schemas):
                if i < len(spec.inputs):
                    actual = self.bus.schema_of(spec.inputs[i])
                    if not schema.accepts(actual):
                        raise CoherenceError(
                            f"stream {spec.name!r}: input {spec.inputs[i]!r} schema "
                            f"incompatible with AU {au.name!r} input {i}")
            self._streams[spec.name] = spec
            self._resolved[spec.name] = resolved
        self.bus.register_subject(spec.name, au.output_schema)
        if spec.durable:
            self._make_durable(spec.name, spec.retention)
        n = spec.fixed_instances if spec.fixed_instances is not None else au.min_instances
        for _ in range(max(1, n)):
            self._spawn_au(spec, au, resolved)
        fused = (f", fused={list(au.fused_stages)}" if au.fused_stages else "")
        self._event("register", f"stream/{spec.name} (au={spec.analytics_unit}, "
                                f"inputs={list(spec.inputs)}{fused})")

    def _spawn_au(self, spec: StreamSpec, au: AnalyticsUnitSpec,
                  resolved: Mapping[str, Any]) -> InstanceHandle:
        db = None
        if au.stateful:
            db_name = f"au-{spec.name}"
            db = (self.store.get(db_name) if self.store.exists(db_name)
                  else self.store.create(db_name))
        # replay_from="snapshot" resolves at SPAWN time against the stream's
        # state database: a restarted/crashed member replays only the log
        # suffix after the last recovery watermark (falling back to
        # "earliest" before any snapshot exists).  Replaying from an
        # older-than-necessary offset is safe — KeyedStore.apply_once
        # discards already-applied offsets — so the watermark is purely an
        # efficiency bound, never a correctness one.
        replay_from = resolve_replay_from(spec.replay_from, db)
        # group/keyed delivery: every instance of this stream (fused units
        # included — one member per instance) joins the queue group named
        # after the stream, so scaled instances form a worker pool on their
        # inputs; under "keyed" the group hashes spec.key so each key sticks
        # to one instance (all instances share the stream's platform
        # database, so a rebalanced partition finds its per-key state).
        # Other streams consuming the same inputs use their own group names
        # and still see every message (§3 reuse broadcast across groups).
        # The typed policy carries spec.steal through to bus.subscribe —
        # the legacy group=/key= spelling had no way to say it.
        if spec.delivery == "keyed":
            policy = Keyed(spec.name, spec.key, steal=spec.steal)
        elif spec.delivery == "group":
            policy = Group(spec.name, steal=spec.steal)
        else:
            policy = None
        handle = self.executor.start_instance(
            entity_kind="analytics_unit", entity_name=au.name, owner=spec.name,
            logic=au.logic, config=dict(resolved), inputs=tuple(spec.inputs),
            output=spec.name, db=db or self._db_for(resolved),
            policy=policy, max_batch=spec.max_batch, replay_from=replay_from)
        diags = self._diag_by_node.get(f"stream/{spec.name}")
        if diags:
            handle.sidecar.note_diagnostics(diags)
        return handle

    def register_gadget(self, spec: GadgetSpec) -> None:
        """Create a gadget: validate its actuator + input streams and
        start actuator instances pooled under the gadget's name."""
        with self._lock:
            if spec.name in self._gadgets:
                raise OperatorError(f"gadget {spec.name!r} already registered")
            if spec.actuator not in self._actuators:
                raise CoherenceError(
                    f"gadget {spec.name!r}: actuator {spec.actuator!r} not available")
            act = self._actuators[spec.actuator]
            missing = [s for s in spec.inputs if s not in self._stream_names()]
            if missing:
                raise CoherenceError(
                    f"gadget {spec.name!r}: input streams not registered: {missing}")
            resolved = act.config_schema.validate(spec.config)
            self._gadgets[spec.name] = spec
            self._resolved[spec.name] = resolved
        # actuator instances pool under the gadget's name too, so a scaled
        # gadget actuates once per insight instead of once per replica; the
        # kind prefix keeps a gadget from merging into the queue group of a
        # same-named stream that consumes the same subjects (gadget and
        # stream names live in different namespaces)
        self.executor.start_instance(
            entity_kind="actuator", entity_name=act.name, owner=spec.name,
            logic=act.logic, config=dict(resolved), inputs=tuple(spec.inputs),
            output=None, db=self._db_for(resolved),
            group=f"gadget:{spec.name}")
        self._event("register", f"gadget/{spec.name} (actuator={spec.actuator})")

    def create_database(self, spec: DatabaseSpec) -> Database:
        """Create a platform-managed database entity (memkv or filekv)."""
        with self._lock:
            if spec.name in self._databases:
                raise OperatorError(f"database {spec.name!r} already registered")
            self._databases[spec.name] = spec
        db = self.store.create(spec.name, engine=spec.engine, tables=spec.tables)
        self._event("register", f"database/{spec.name} ({spec.engine})")
        return db

    def _db_for(self, resolved: Mapping[str, Any]) -> Database | None:
        """Entities reference a platform database via config key 'database'."""
        name = resolved.get("database")
        if isinstance(name, str) and name and self.store.exists(name):
            return self.store.get(name)
        return None

    # -- deletion with coherence ------------------------------------------------
    def delete_sensor(self, name: str) -> None:
        """Remove a sensor and its subject; refused while downstream
        streams/gadgets consume it."""
        with self._lock:
            if name not in self._sensors:
                raise OperatorError(f"sensor {name!r} not registered")
            self._refuse_if_feeding(name)
            del self._sensors[name]
            self._resolved.pop(name, None)
        self._teardown_owner(name)
        self.bus.unregister_subject(name)
        self._event("delete", f"sensor/{name}")

    def delete_stream(self, name: str) -> None:
        """Remove a stream and its subject; refused while downstream
        streams/gadgets consume it."""
        with self._lock:
            if name not in self._streams:
                raise OperatorError(f"stream {name!r} not registered")
            self._refuse_if_feeding(name)
            del self._streams[name]
            self._resolved.pop(name, None)
        self._teardown_owner(name)
        self.bus.unregister_subject(name)
        self._event("delete", f"stream/{name}")

    def delete_gadget(self, name: str) -> None:
        """Remove a gadget and tear down its actuator instances."""
        with self._lock:
            if name not in self._gadgets:
                raise OperatorError(f"gadget {name!r} not registered")
            del self._gadgets[name]
            self._resolved.pop(name, None)
        self._teardown_owner(name)
        self._event("delete", f"gadget/{name}")

    def _refuse_if_feeding(self, name: str) -> None:
        consumers = [s.name for s in self._streams.values() if name in s.inputs]
        consumers += [g.name for g in self._gadgets.values() if name in g.inputs]
        if consumers:
            raise CoherenceError(
                f"cannot delete {name!r}: it feeds {sorted(consumers)}")

    def _teardown_owner(self, owner: str) -> None:
        for h in self.executor.instances_of(owner):
            self.executor.stop_instance(h.instance_id)

    def _restart_owner(self, owner: str) -> None:
        self._teardown_owner(owner)
        with self._lock:
            if owner in self._sensors:
                spec = self._sensors[owner]
                driver = self._drivers[spec.driver]
                resolved = self._resolved[owner]
                spawn = lambda: self._spawn_driver(spec, driver, resolved)
                count = 1
            elif owner in self._streams:
                spec = self._streams[owner]
                au = self._aus[spec.analytics_unit]
                resolved = self._resolved[owner]
                spawn = lambda: self._spawn_au(spec, au, resolved)
                count = (spec.fixed_instances if spec.fixed_instances is not None
                         else au.min_instances)
            else:
                return
        for _ in range(max(1, count)):
            spawn()

    # =====================================================================
    # Reconciliation — reliability, autoscaling, stragglers
    # =====================================================================

    def start(self) -> None:
        """Start the background reconcile loop (restart crashed
        instances, autoscale, replace stragglers); idempotent."""
        if self._reconciler is not None:
            return
        self._stop.clear()
        self._reconciler = threading.Thread(
            target=self._reconcile_loop, name="datax-operator", daemon=True)
        self._reconciler.start()

    def _reconcile_loop(self) -> None:
        while not self._stop.wait(self._reconcile_interval_s):
            try:
                self.reconcile_once()
            except Exception as e:  # the operator itself must not die
                self._event("reconcile-error", repr(e))

    def reconcile_once(self) -> None:
        """One reconcile pass: restart crashed instances, apply
        autoscaling decisions, replace stragglers.  The loop started by
        :meth:`start` calls this; tests call it directly."""
        self._restart_crashed()
        self._apply_autoscale()
        self._replace_stragglers()

    def _restart_crashed(self) -> None:
        dead = self.executor.reap_dead()
        with self._lock:
            # completed instances (finite sources that ran to a normal end)
            # are NOT restarted — only crashed ones violate desired state.
            owners = {h.owner for h in dead
                      if h.crashed
                      and (h.owner in self._sensors or h.owner in self._streams
                           or h.owner in self._gadgets)}
        for h in dead:
            if h.crashed:
                self._event("crash", f"{h.instance_id}: {h.crash_info.splitlines()[-1] if h.crash_info else '?'}")
        for owner in owners:
            # desired state says this entity should be running -> restart (§4
            # "reliably operate")
            live = self.executor.instances_of(owner)
            if not live:
                self._restart_owner(owner)
                self._event("restart", owner)

    def _apply_autoscale(self) -> None:
        with self._lock:
            streams = list(self._streams.values())
        for spec in streams:
            if spec.fixed_instances is not None:
                continue  # §4: unless the user requests a fixed number
            with self._lock:
                au = self._aus.get(spec.analytics_unit)
                resolved = self._resolved.get(spec.name, {})
            if au is None:
                continue
            if au.placement is Placement.DEVICE and not au.fused_stages:
                continue  # bare device AUs are mesh-managed, not thread-scaled
            # a fused unit autoscales as a WHOLE: one decision for the whole
            # segment (its min/max were folded from the stage specs), never
            # per interior hop — those hops no longer exist on the bus.
            # Under the default delivery="group" the instances form a bus
            # queue group (single delivery), so every scale-up adds capacity;
            # the AutoScaler's signals are group-aggregate accordingly.
            handles = self.executor.instances_of(spec.name)
            desired = self.autoscaler.decide(spec.name, handles,
                                             au.min_instances, au.max_instances)
            cur = len(handles)
            if desired > cur:
                for _ in range(desired - cur):
                    self._spawn_au(spec, au, resolved)
                self._event("scale-up", f"{spec.name}: {cur} -> {desired}")
            elif desired < cur:
                for h in handles[: cur - desired]:
                    self.executor.stop_instance(h.instance_id)
                self._event("scale-down", f"{spec.name}: {cur} -> {desired}")

    def _replace_stragglers(self) -> None:
        """Mark instances whose latency EWMA ≫ peer median, replace them."""
        with self._lock:
            streams = list(self._streams.values())
        for spec in streams:
            handles = self.executor.instances_of(spec.name)
            if len(handles) < 3:
                continue  # need peers to define a median
            lat = sorted(h.sidecar.latency_ewma_s for h in handles)
            median = lat[len(lat) // 2]
            if median <= 0:
                continue
            for h in handles:
                if (h.sidecar.latency_ewma_s > self.straggler_factor * median
                        and h.sidecar.processed >= 4):
                    with self._lock:
                        au = self._aus.get(spec.analytics_unit)
                        resolved = self._resolved.get(spec.name, {})
                    if au is None:
                        continue
                    self.executor.stop_instance(h.instance_id)
                    self._spawn_au(spec, au, resolved)
                    self._event("straggler", f"replaced {h.instance_id} "
                                             f"(ewma {h.sidecar.latency_ewma_s:.4f}s "
                                             f"vs median {median:.4f}s)")

    # =====================================================================
    # Cross-host transport
    # =====================================================================

    def serve(self, host: str = "127.0.0.1", port: int = 0, *,
              window: int | None = None,
              hb_timeout: float = 10.0) -> tuple[str, int]:
        """Expose this deployment's bus over TCP so other processes can join.

        Starts a :class:`~.transport.BusServer` wrapping :attr:`bus`; remote
        processes (:class:`~.serverless.RemoteWorker`, or a bare
        :class:`~.transport.RemoteBus`) then subscribe to any registered
        stream as first-class queue-group / keyed-ring members — the
        cross-host worker-pool story.  Idempotent; returns the bound
        ``(host, port)`` (``port=0`` lets the OS pick).  The server is torn
        down by :meth:`shutdown`."""
        from .transport import DEFAULT_WINDOW, BusServer
        with self._lock:
            if self._bus_server is not None:
                return self._bus_server.address
            self._bus_server = BusServer(
                self.bus, host, port, window=window or DEFAULT_WINDOW,
                hb_timeout=hb_timeout)
            addr = self._bus_server.address
        self._event("serve", f"bus exposed at {addr[0]}:{addr[1]}")
        return addr

    @property
    def bus_address(self) -> tuple[str, int] | None:
        """The served bus's ``(host, port)``, or None before :meth:`serve`."""
        with self._lock:
            return None if self._bus_server is None else self._bus_server.address

    def transport_stats(self) -> dict | None:
        """Server-side federated transport metrics (per-peer connection
        state, frames/bytes in/out, reaps); None before :meth:`serve`."""
        with self._lock:
            server = self._bus_server
        return None if server is None else server.stats()

    # =====================================================================
    # Introspection / shutdown
    # =====================================================================

    def describe(self) -> dict:
        """Registered-entity snapshot: versions per code entity, names of
        sensors/streams/gadgets/databases, live instance ids."""
        with self._lock:
            return {
                "drivers": {n: s.version for n, s in self._drivers.items()},
                "analytics_units": {n: s.version for n, s in self._aus.items()},
                "actuators": {n: s.version for n, s in self._actuators.items()},
                "sensors": sorted(self._sensors),
                "streams": sorted(self._streams),
                "gadgets": sorted(self._gadgets),
                "databases": sorted(self._databases),
                "instances": [h.instance_id for h in self.executor.all_instances()],
                "diagnostics": {
                    app: {
                        "error": sum(1 for d in diags
                                     if d["severity"] == "error"),
                        "warning": sum(1 for d in diags
                                       if d["severity"] == "warning"),
                        "info": sum(1 for d in diags
                                    if d["severity"] == "info"),
                    } for app, diags in self._diagnostics.items()},
            }

    def record_diagnostics(self, app_name: str, diagnostics) -> None:
        """Record an app's ``datax check`` diagnostic summary at deploy time.

        ``Application.deploy`` calls this with the analyzer's findings so
        the flagged hazards stay visible on the running deployment:
        :meth:`diagnostics` returns the full records, :meth:`describe`
        carries per-app severity counts, and instances spawned afterwards
        expose their own stream's findings in sidecar ``metrics()``
        (the REST-analog ops surface).  Accepts
        :class:`~.analyze.Diagnostic` records or their ``to_json`` dicts.
        """
        entries = [d.to_json() if hasattr(d, "to_json") else dict(d)
                   for d in diagnostics]
        with self._lock:
            self._diagnostics[app_name] = entries
            self._diag_by_node = {}
            for diags in self._diagnostics.values():
                for e in diags:
                    self._diag_by_node.setdefault(e["node"], []).append(e)
        if entries:
            rank = {"info": 0, "warning": 1, "error": 2}
            worst = max((e["severity"] for e in entries),
                        key=lambda s: rank.get(s, -1))
            self._event("diagnostics",
                        f"app/{app_name} ({len(entries)} finding(s), "
                        f"worst={worst})")

    def diagnostics(self) -> dict:
        """Deploy-time ``datax check`` findings per app name (JSON dicts,
        see :meth:`record_diagnostics`)."""
        with self._lock:
            return {app: list(diags)
                    for app, diags in self._diagnostics.items()}

    def registered_streams(self) -> list[str]:
        """Everything subscribable — the paper's stream-reuse surface (§3)."""
        return sorted(self._stream_names())

    def metrics(self) -> dict:
        """Per-instance sidecar metrics keyed by instance id (docs/metrics.md)."""
        return {h.instance_id: h.sidecar.metrics()
                for h in self.executor.all_instances()}

    def subscribe(self, stream: str, *, name: str = "external",
                  maxsize: int = 256, policy=None, replay=None,
                  replay_from=None):
        """Third-party subscription to any registered stream (§3 reuse).

        ``policy`` (a typed :class:`~.delivery.DeliveryPolicy`) lets the
        external consumer join the subject under group/keyed delivery; the
        default is broadcast.  On a durable stream,
        ``replay=ReplayFrom.offset(n)`` / ``.timestamp(ts)`` /
        ``.earliest()`` serves the retained history first, then flips to
        live delivery — the late-joining-consumer story.  The deprecated
        ``replay_from=`` raw values keep working with a warning."""
        replay_value = resolve_replay(replay, replay_from)
        token = self.bus.issue_token(name, [stream])
        return self.bus.subscribe(
            stream, token=token, maxsize=maxsize, name=name, policy=policy,
            replay=ReplayFrom(replay_value)
            if replay_value is not None else None)

    def shutdown(self) -> None:
        """Stop the reconciler, the bus server (reaping remote members),
        every instance, and finally the bus itself."""
        self._stop.set()
        if self._reconciler is not None:
            self._reconciler.join(timeout=2.0)
            self._reconciler = None
        with self._lock:
            server, self._bus_server = self._bus_server, None
        if server is not None:
            server.close()
        self.executor.shutdown()
        self.bus.close()
