"""StateStore — the paper's platform-managed database abstraction (§2, §3).

"DataX makes this state management easy by exposing in-built database
management systems and the associated databases.  Developers can choose the
specific database, create the desired schema, and manage the desired
content/state."

Two engines:

* ``memkv``  — in-memory, thread-safe table store (row dicts, per-table locks)
* ``filekv`` — same API, persisted to compressed msgpack files (zstd when
               available, stdlib zlib otherwise; see ``compression.py``) so
               state survives restarts (checkpoint metadata + fault tests)

The training/serving substrates reuse this as their state backbone: optimizer
state manifests, KV-cache registries and serving session tables are all DataX
databases — the paper's claim "state management within and across AUs".
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Iterable, Mapping

import msgpack

from .bus import _default, _ext_hook  # reuse the numpy-aware wire format
from .compression import codec_name, compress, decompress


class StateError(RuntimeError):
    pass


class Table:
    """A named table with primary-key rows and optional declared columns."""

    def __init__(self, name: str, columns: Iterable[str] | None = None):
        self.name = name
        self.columns = tuple(columns) if columns else None
        self._rows: dict[Any, dict] = {}
        self._lock = threading.RLock()

    def put(self, key: Any, row: Mapping[str, Any]) -> None:
        if self.columns is not None:
            unknown = set(row) - set(self.columns)
            if unknown:
                raise StateError(f"table {self.name!r}: unknown columns {sorted(unknown)}")
        with self._lock:
            self._rows[key] = dict(row)

    def get(self, key: Any, default: Any = None) -> dict | None:
        with self._lock:
            row = self._rows.get(key)
            return dict(row) if row is not None else default

    def update(self, key: Any, **fields: Any) -> dict:
        with self._lock:
            if key not in self._rows:
                raise StateError(f"table {self.name!r}: no row {key!r}")
            self._rows[key].update(fields)
            return dict(self._rows[key])

    def widen(self, columns: Iterable[str]) -> None:
        """Add declared columns (idempotent) — schema evolution for tables
        loaded from an older on-disk layout (e.g. KeyedStore rows that
        predate ts/offset tracking)."""
        with self._lock:
            if self.columns is None:
                return
            self.columns = tuple(dict.fromkeys((*self.columns, *columns)))

    def delete(self, key: Any) -> None:
        with self._lock:
            self._rows.pop(key, None)

    def scan(self, predicate=None) -> list[tuple[Any, dict]]:
        with self._lock:
            items = [(k, dict(v)) for k, v in self._rows.items()]
        if predicate is not None:
            items = [(k, v) for k, v in items if predicate(k, v)]
        return items

    def __len__(self) -> int:
        with self._lock:
            return len(self._rows)

    # -- (de)serialization ---------------------------------------------------
    def to_obj(self) -> dict:
        with self._lock:
            return {"name": self.name, "columns": self.columns,
                    "rows": [(k, v) for k, v in self._rows.items()]}

    @staticmethod
    def from_obj(obj: dict) -> "Table":
        t = Table(obj["name"], obj["columns"])
        for k, v in obj["rows"]:
            t._rows[k] = v
        return t


class Database:
    """One database: a set of tables behind a single name."""

    def __init__(self, name: str, engine: str = "memkv", path: str | None = None):
        if engine not in ("memkv", "filekv"):
            raise StateError(f"unknown engine {engine!r}")
        self.name = name
        self.engine = engine
        self.path = path
        self._tables: dict[str, Table] = {}
        self._lock = threading.RLock()
        if engine == "filekv":
            if not path:
                raise StateError("filekv engine needs a path")
            if os.path.exists(path):
                self._load()

    def create_table(self, name: str, columns: Iterable[str] | None = None) -> Table:
        with self._lock:
            if name in self._tables:
                raise StateError(f"table {name!r} exists")
            t = Table(name, columns)
            self._tables[name] = t
            return t

    def table(self, name: str) -> Table:
        with self._lock:
            if name not in self._tables:
                raise StateError(f"no table {name!r} in database {self.name!r}")
            return self._tables[name]

    def ensure_table(self, name: str, columns: Iterable[str] | None = None) -> Table:
        with self._lock:
            if name not in self._tables:
                return self.create_table(name, columns)
            return self._tables[name]

    def tables(self) -> list[str]:
        with self._lock:
            return sorted(self._tables)

    def drop_table(self, name: str) -> None:
        with self._lock:
            self._tables.pop(name, None)

    # -- persistence (filekv) -------------------------------------------------
    def flush(self) -> None:
        if self.engine != "filekv":
            return
        with self._lock:
            obj = {"name": self.name, "ts": time.time(), "codec": codec_name(),
                   "tables": [t.to_obj() for t in self._tables.values()]}
        blob = compress(
            msgpack.packb(obj, default=_default, use_bin_type=True), level=3)
        tmp = f"{self.path}.tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self.path)  # atomic commit

    def _load(self) -> None:
        with open(self.path, "rb") as f:
            blob = f.read()
        obj = msgpack.unpackb(decompress(blob),
                              ext_hook=_ext_hook, raw=False, strict_map_key=False)
        for tobj in obj["tables"]:
            t = Table.from_obj(tobj)
            self._tables[t.name] = t


#: Table where KeyedStore.snapshot records per-owner watermarks — kept in
#: sync with durable.SNAPSHOT_TABLE (duplicated literal to avoid an import
#: cycle at module load; asserted equal in the test suite).
SNAPSHOT_TABLE = "__snapshots__"


class KeyedStore:
    """Per-key state over a platform table — the keyed-combinator backbone.

    Keyed stateful combinators (``.window(per_key=True)``, keyed
    ``.reduce``) keep their state here instead of in instance-local
    closures: every instance of a keyed stream shares the stream's platform
    database, so when a scale event moves a partition to another instance,
    the new owner reads exactly the state the old owner wrote — rebalances
    hand state over instead of losing it.  Keyed delivery guarantees a key
    is only ever processed by one instance at a time, so per-key get/put
    needs no cross-instance coordination.

    Rows carry bookkeeping beyond the value: ``ts`` (last write, drives TTL
    expiry) and ``offset`` (the durable-log position of the last applied
    update — the exactly-once recovery watermark).

    **Bounded growth** (long-tail keys must not grow the platform DB
    forever): ``ttl=`` seconds expires keys lazily on access and in a
    :meth:`compact` sweep; ``max_keys=`` evicts the oldest-written keys on
    insert.  Snapshots purge expired keys before persisting.

    **Exactly-once application** (:meth:`apply_once`): the per-key fold runs
    atomically under the key's stripe lock, guarded by the row's applied
    offset — a durable-log replay that overlaps live delivery (or a
    rebalance racing a recovery) can never double-apply an update, no
    matter which copy arrives first.  Distinct keys fold in parallel; only
    the brief row read/write takes the table-wide lock.

    ``db=None`` falls back to a private in-memory database (unit tests /
    factories exercised outside an operator); state then lives only as long
    as the process, exactly like the old closure dicts.
    """

    COLUMNS = ("value", "ts", "offset")

    def __init__(self, db: Database | None, name: str, *,
                 ttl: float | None = None, max_keys: int | None = None):
        if ttl is not None and ttl <= 0:
            raise StateError(f"ttl must be positive, got {ttl}")
        if max_keys is not None and max_keys < 1:
            raise StateError(f"max_keys must be >= 1, got {max_keys}")
        self._db = db or Database(f"local-{name}")
        self._table = self._db.ensure_table(name, self.COLUMNS)
        self._table.widen(self.COLUMNS)  # pre-TTL tables lack ts/offset
        self.ttl = ttl
        self.max_keys = max_keys
        self.expired = 0   # keys dropped by TTL (lazy + compaction)
        self.evicted = 0   # keys dropped by max_keys pressure
        # stripe locks serialize apply_once per KEY while letting distinct
        # keys fold in parallel — user fold fns can be slow (I/O, service
        # time) and must not hold the table-wide lock
        self._stripes = [threading.Lock() for _ in range(16)]

    # -- TTL / eviction internals -------------------------------------------
    def _fresh(self, row: dict | None, now: float | None = None) -> bool:
        if row is None:
            return False
        if self.ttl is None:
            return True
        ts = row.get("ts")
        if ts is None:  # legacy row written before ts tracking: never expires
            return True
        return (now if now is not None else time.time()) - ts <= self.ttl

    def _expire_locked(self, key: Any, row: dict | None) -> dict | None:
        if row is not None and not self._fresh(row):
            self._table.delete(key)
            self.expired += 1
            return None
        return row

    def _evict_overflow_locked(self, keep: Any) -> None:
        if self.max_keys is None:
            return
        while len(self._table) > self.max_keys:
            victim, oldest = None, None
            for k, row in self._table.scan():
                if k == keep:
                    continue
                ts = row.get("ts") or 0.0
                if oldest is None or ts < oldest:
                    victim, oldest = k, ts
            if victim is None:
                return
            self._table.delete(victim)
            self.evicted += 1

    # -- per-key API ---------------------------------------------------------
    def get(self, key: Any, default: Any = None) -> Any:
        """The key's current value (``default`` for absent or TTL-expired
        keys)."""
        with self._table._lock:
            row = self._expire_locked(key, self._table.get(key))
        return row["value"] if row is not None else default

    def put(self, key: Any, value: Any, *, offset: int | None = None) -> None:
        """Set the key's value; ``offset`` stamps the durable-log position
        this update reflects (kept from the previous row when omitted) so
        :meth:`apply_once` can dedupe replays."""
        with self._table._lock:
            if offset is None:
                prev = self._table.get(key)
                if prev is not None:
                    offset = prev.get("offset")
            self._table.put(key, {"value": value, "ts": time.time(),
                                  "offset": offset})
            self._evict_overflow_locked(keep=key)

    def applied_offset(self, key: Any) -> int | None:
        """The durable-log offset of the last update applied to ``key``."""
        row = self._table.get(key)
        return row.get("offset") if row is not None else None

    def apply_once(self, key: Any, offset: int | None, fn,
                   init: Any = None) -> tuple[Any, bool]:
        """Atomically fold ``fn(current_value) -> new_value`` into ``key``,
        unless log position ``offset`` was already applied.

        Returns ``(value, applied)``.  ``applied=False`` means the update at
        ``offset`` is already reflected in ``value`` — the caller must also
        skip its side effects (downstream emission) to keep the whole stage
        exactly-once.  The check-and-fold holds the key's stripe lock, so a
        replay racing live delivery of the same offset applies it exactly
        once regardless of interleaving — but NOT the table-wide lock while
        ``fn`` runs, so slow folds on distinct keys proceed in parallel
        (the whole point of keyed scaling).  ``offset=None`` (non-durable
        input) always applies.
        """
        with self._stripes[hash(key) % len(self._stripes)]:
            with self._table._lock:
                row = self._expire_locked(key, self._table.get(key))
            applied = row.get("offset") if row is not None else None
            if offset is not None and applied is not None \
                    and offset <= applied:
                return row["value"], False
            value = fn(row["value"] if row is not None else init)
            with self._table._lock:
                self._table.put(key, {
                    "value": value, "ts": time.time(),
                    "offset": offset if offset is not None else applied})
                self._evict_overflow_locked(keep=key)
            return value, True

    def delete(self, key: Any) -> None:
        """Drop the key's state (and its applied-offset watermark)."""
        self._table.delete(key)

    def keys(self) -> list:
        """All live (non-expired) keys."""
        now = time.time()
        return [k for k, row in self._table.scan()
                if self._fresh(row, now)]

    def __len__(self) -> int:
        return len(self._table)

    # -- maintenance ---------------------------------------------------------
    def compact(self) -> int:
        """Sweep expired keys out (the compaction hook — called by the
        sidecar's housekeeping and before snapshots); returns keys removed."""
        if self.ttl is None:
            return 0
        removed = 0
        now = time.time()
        with self._table._lock:
            for k, row in self._table.scan():
                if not self._fresh(row, now):
                    self._table.delete(k)
                    removed += 1
        self.expired += removed
        return removed

    def stats(self) -> dict:
        """Bounded-state accounting: live key count, configured ``ttl`` /
        ``max_keys``, and how many keys expired or were evicted."""
        return {"keys": len(self._table), "ttl": self.ttl,
                "max_keys": self.max_keys, "expired": self.expired,
                "evicted": self.evicted}

    # -- exactly-once recovery snapshots -------------------------------------
    def snapshot(self, owner: str, offset: int) -> dict:
        """Record that every durable-log offset <= ``offset`` is reflected
        in this store (the recovery watermark for ``owner``), purging
        expired keys first and flushing the database if it persists.

        The platform database itself *is* the state snapshot — instances of
        a stream share it, so recovery only needs the watermark: a restarted
        member replays the log suffix after ``min(watermarks)`` and
        :meth:`apply_once` discards the prefix each key already absorbed.
        """
        self.compact()
        marks = self._db.ensure_table(SNAPSHOT_TABLE, ["watermark", "ts"])
        marks.put(owner, {"watermark": int(offset), "ts": time.time()})
        self._db.flush()
        return {"owner": owner, "watermark": int(offset),
                "keys": len(self._table)}

    def last_snapshot(self, owner: str | None = None) -> dict | None:
        """The newest watermark row (for ``owner``, or any) — the sidecar's
        snapshot-age metric reads this."""
        try:
            marks = self._db.table(SNAPSHOT_TABLE)
        except StateError:
            return None
        rows = [row for k, row in marks.scan()
                if owner is None or k == owner]
        if not rows:
            return None
        return max(rows, key=lambda r: r.get("ts", 0.0))


class StateStore:
    """Platform-level registry of databases; the Operator installs them."""

    def __init__(self, root: str | None = None):
        self._dbs: dict[str, Database] = {}
        self._lock = threading.RLock()
        self._root = root

    def create(self, name: str, engine: str = "memkv",
               tables: Mapping[str, Iterable[str]] | None = None) -> Database:
        with self._lock:
            if name in self._dbs:
                raise StateError(f"database {name!r} exists")
            path = None
            if engine == "filekv":
                if not self._root:
                    raise StateError("StateStore has no root dir for filekv databases")
                os.makedirs(self._root, exist_ok=True)
                path = os.path.join(self._root, f"{name}.dxdb")
            db = Database(name, engine, path)
            for tname, cols in (tables or {}).items():
                db.ensure_table(tname, cols)
            self._dbs[name] = db
            return db

    def get(self, name: str) -> Database:
        with self._lock:
            if name not in self._dbs:
                raise StateError(f"no database {name!r}")
            return self._dbs[name]

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._dbs

    def drop(self, name: str) -> None:
        with self._lock:
            db = self._dbs.pop(name, None)
        if db is not None and db.engine == "filekv" and db.path and os.path.exists(db.path):
            os.remove(db.path)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._dbs)
