"""StateStore — the paper's platform-managed database abstraction (§2, §3).

"DataX makes this state management easy by exposing in-built database
management systems and the associated databases.  Developers can choose the
specific database, create the desired schema, and manage the desired
content/state."

Two engines:

* ``memkv``  — in-memory, thread-safe table store (row dicts, per-table locks)
* ``filekv`` — same API, persisted to compressed msgpack files (zstd when
               available, stdlib zlib otherwise; see ``compression.py``) so
               state survives restarts (checkpoint metadata + fault tests)

The training/serving substrates reuse this as their state backbone: optimizer
state manifests, KV-cache registries and serving session tables are all DataX
databases — the paper's claim "state management within and across AUs".
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Iterable, Mapping

import msgpack

from .bus import _default, _ext_hook  # reuse the numpy-aware wire format
from .compression import codec_name, compress, decompress


class StateError(RuntimeError):
    pass


class Table:
    """A named table with primary-key rows and optional declared columns."""

    def __init__(self, name: str, columns: Iterable[str] | None = None):
        self.name = name
        self.columns = tuple(columns) if columns else None
        self._rows: dict[Any, dict] = {}
        self._lock = threading.RLock()

    def put(self, key: Any, row: Mapping[str, Any]) -> None:
        if self.columns is not None:
            unknown = set(row) - set(self.columns)
            if unknown:
                raise StateError(f"table {self.name!r}: unknown columns {sorted(unknown)}")
        with self._lock:
            self._rows[key] = dict(row)

    def get(self, key: Any, default: Any = None) -> dict | None:
        with self._lock:
            row = self._rows.get(key)
            return dict(row) if row is not None else default

    def update(self, key: Any, **fields: Any) -> dict:
        with self._lock:
            if key not in self._rows:
                raise StateError(f"table {self.name!r}: no row {key!r}")
            self._rows[key].update(fields)
            return dict(self._rows[key])

    def delete(self, key: Any) -> None:
        with self._lock:
            self._rows.pop(key, None)

    def scan(self, predicate=None) -> list[tuple[Any, dict]]:
        with self._lock:
            items = [(k, dict(v)) for k, v in self._rows.items()]
        if predicate is not None:
            items = [(k, v) for k, v in items if predicate(k, v)]
        return items

    def __len__(self) -> int:
        with self._lock:
            return len(self._rows)

    # -- (de)serialization ---------------------------------------------------
    def to_obj(self) -> dict:
        with self._lock:
            return {"name": self.name, "columns": self.columns,
                    "rows": [(k, v) for k, v in self._rows.items()]}

    @staticmethod
    def from_obj(obj: dict) -> "Table":
        t = Table(obj["name"], obj["columns"])
        for k, v in obj["rows"]:
            t._rows[k] = v
        return t


class Database:
    """One database: a set of tables behind a single name."""

    def __init__(self, name: str, engine: str = "memkv", path: str | None = None):
        if engine not in ("memkv", "filekv"):
            raise StateError(f"unknown engine {engine!r}")
        self.name = name
        self.engine = engine
        self.path = path
        self._tables: dict[str, Table] = {}
        self._lock = threading.RLock()
        if engine == "filekv":
            if not path:
                raise StateError("filekv engine needs a path")
            if os.path.exists(path):
                self._load()

    def create_table(self, name: str, columns: Iterable[str] | None = None) -> Table:
        with self._lock:
            if name in self._tables:
                raise StateError(f"table {name!r} exists")
            t = Table(name, columns)
            self._tables[name] = t
            return t

    def table(self, name: str) -> Table:
        with self._lock:
            if name not in self._tables:
                raise StateError(f"no table {name!r} in database {self.name!r}")
            return self._tables[name]

    def ensure_table(self, name: str, columns: Iterable[str] | None = None) -> Table:
        with self._lock:
            if name not in self._tables:
                return self.create_table(name, columns)
            return self._tables[name]

    def tables(self) -> list[str]:
        with self._lock:
            return sorted(self._tables)

    def drop_table(self, name: str) -> None:
        with self._lock:
            self._tables.pop(name, None)

    # -- persistence (filekv) -------------------------------------------------
    def flush(self) -> None:
        if self.engine != "filekv":
            return
        with self._lock:
            obj = {"name": self.name, "ts": time.time(), "codec": codec_name(),
                   "tables": [t.to_obj() for t in self._tables.values()]}
        blob = compress(
            msgpack.packb(obj, default=_default, use_bin_type=True), level=3)
        tmp = f"{self.path}.tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self.path)  # atomic commit

    def _load(self) -> None:
        with open(self.path, "rb") as f:
            blob = f.read()
        obj = msgpack.unpackb(decompress(blob),
                              ext_hook=_ext_hook, raw=False, strict_map_key=False)
        for tobj in obj["tables"]:
            t = Table.from_obj(tobj)
            self._tables[t.name] = t


class KeyedStore:
    """Per-key state over a platform table — the keyed-combinator backbone.

    Keyed stateful combinators (``.window(per_key=True)``, keyed
    ``.reduce``) keep their state here instead of in instance-local
    closures: every instance of a keyed stream shares the stream's platform
    database, so when a scale event moves a partition to another instance,
    the new owner reads exactly the state the old owner wrote — rebalances
    hand state over instead of losing it.  Keyed delivery guarantees a key
    is only ever processed by one instance at a time, so per-key get/put
    needs no cross-instance coordination.

    ``db=None`` falls back to a private in-memory database (unit tests /
    factories exercised outside an operator); state then lives only as long
    as the process, exactly like the old closure dicts.
    """

    def __init__(self, db: Database | None, name: str):
        self._db = db or Database(f"local-{name}")
        self._table = self._db.ensure_table(name, ["value"])

    def get(self, key: Any, default: Any = None) -> Any:
        row = self._table.get(key)
        return row["value"] if row is not None else default

    def put(self, key: Any, value: Any) -> None:
        self._table.put(key, {"value": value})

    def delete(self, key: Any) -> None:
        self._table.delete(key)

    def keys(self) -> list:
        return [k for k, _ in self._table.scan()]

    def __len__(self) -> int:
        return len(self._table)


class StateStore:
    """Platform-level registry of databases; the Operator installs them."""

    def __init__(self, root: str | None = None):
        self._dbs: dict[str, Database] = {}
        self._lock = threading.RLock()
        self._root = root

    def create(self, name: str, engine: str = "memkv",
               tables: Mapping[str, Iterable[str]] | None = None) -> Database:
        with self._lock:
            if name in self._dbs:
                raise StateError(f"database {name!r} exists")
            path = None
            if engine == "filekv":
                if not self._root:
                    raise StateError("StateStore has no root dir for filekv databases")
                os.makedirs(self._root, exist_ok=True)
                path = os.path.join(self._root, f"{name}.dxdb")
            db = Database(name, engine, path)
            for tname, cols in (tables or {}).items():
                db.ensure_table(tname, cols)
            self._dbs[name] = db
            return db

    def get(self, name: str) -> Database:
        with self._lock:
            if name not in self._dbs:
                raise StateError(f"no database {name!r}")
            return self._dbs[name]

    def exists(self, name: str) -> bool:
        with self._lock:
            return name in self._dbs

    def drop(self, name: str) -> None:
        with self._lock:
            db = self._dbs.pop(name, None)
        if db is not None and db.engine == "filekv" and db.path and os.path.exists(db.path):
            os.remove(db.path)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._dbs)
