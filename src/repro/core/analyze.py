"""``datax check`` — build-time dataflow diagnostics over the spec graph.

DataX's abstraction "exposes parallelism and dependencies among the
application functions"; this module is the pass that *audits* that graph
instead of merely executing it.  It walks a compiled v1
:class:`~.app.Application` (post-``App.build()``, pre-deploy) through a
registry of rules and emits structured :class:`Diagnostic` records with
stable ``DXnnn`` codes, so a broadcast stream feeding a keyed reduce, a
``.replay()`` on a never-durable subject, or a :class:`~.schema.ShardSpec`
that can never divide its field surfaces at build time instead of as
runtime misbehavior.

Rule families (catalog with examples in ``docs/diagnostics.md``):

* ``DX1xx`` — ordering / exactly-once hazards (delivery vs statefulness,
  work stealing vs order-sensitive consumers, replay vs durability, keyed
  streams whose key field the producer's schema drops).
* ``DX2xx`` — fusion explainability: why an adjacent DEVICE chain did NOT
  fuse, naming the exact :class:`~.fusion.BarrierReason` (info severity —
  the fusion pass's silent decisions made visible).
* ``DX3xx`` — mesh / sharding / batching (ShardSpec rank + axis sanity,
  ``max_batch`` declarations that silently defeat each other in one fused
  segment).
* ``DX4xx`` — hygiene (dead streams, legacy deprecated spellings caught
  statically, schema fields produced but never consumed).

Three integration layers:

* ``App.build(strict=True)`` raises :class:`DiagnosticsError` on any
  error-severity diagnostic (default ``strict=False`` logs them);
* ``python -m repro.core.analyze <module[:attr]|file.py[:attr]>`` — the CLI
  behind ``tools/datax_check.py``, with ``--json`` output for CI and
  ``# datax: ignore[DXnnn] reason`` source pragmas for vetted exceptions;
* :meth:`~.operator.Operator.record_diagnostics` — ``Application.deploy``
  records the summary on the operator, so ``Operator.describe()`` and each
  instance sidecar's ``metrics()["diagnostics"]`` expose what was flagged.
"""
from __future__ import annotations

import argparse
import dataclasses
import enum
import importlib
import importlib.util
import inspect
import json
import re
import sys
from pathlib import Path
from typing import Any, Callable, Iterable, Iterator

from .app import Application, AppValidationError
from .entities import AnalyticsUnitSpec, Placement, StreamSpec
from .fusion import (consumer_counts, edge_barrier, plan_segments,
                     stream_barrier)
from .schema import StreamSchema


# ---------------------------------------------------------------------------
# Diagnostic records
# ---------------------------------------------------------------------------

class Severity(enum.IntEnum):
    """Diagnostic severity ladder; comparisons follow the int value.

    ``ERROR`` means the graph will misbehave at runtime (lost/duplicated/
    reordered data) — ``App.build(strict=True)`` and the CLI's exit code
    gate on it.  ``WARNING`` means the graph is suspicious but may be
    intentional.  ``INFO`` is explanation, not judgment (e.g. DX201's
    "why didn't this fuse").
    """

    INFO = 10
    WARNING = 20
    ERROR = 30

    @property
    def label(self) -> str:
        """Lowercase name for human/JSON output (``"error"`` etc.)."""
        return self.name.lower()


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of the analyzer: a stable code anchored at a graph node.

    ``node`` uses ``kind/name`` paths (``stream/scores``,
    ``sensor/thermal-cam``, ``field/detector.bbox``) so operators and the
    sidecar REST surface can address findings uniformly.  ``fixit`` is a
    one-line suggested remedy; ``app`` is filled by
    :func:`analyze_application`.
    """

    code: str
    severity: Severity
    node: str
    message: str
    fixit: str = ""
    app: str = ""

    def format(self) -> str:
        """One-line human rendering: ``DX101 error stream/x: message``."""
        head = f"{self.code} {self.severity.label} {self.node}: {self.message}"
        return f"{head}  [fix: {self.fixit}]" if self.fixit else head

    def to_json(self) -> dict:
        """JSON-safe dict (severity as its lowercase label)."""
        return {"code": self.code, "severity": self.severity.label,
                "node": self.node, "message": self.message,
                "fixit": self.fixit, "app": self.app}


class DiagnosticsError(AppValidationError):
    """Raised by ``App.build(strict=True)`` on error-severity diagnostics."""

    def __init__(self, diagnostics: Iterable[Diagnostic]):
        self.diagnostics = [d for d in diagnostics
                            if d.severity >= Severity.ERROR]
        lines = "\n  ".join(d.format() for d in self.diagnostics)
        super().__init__(
            f"datax check found {len(self.diagnostics)} error-severity "
            f"diagnostic(s):\n  {lines}")


def has_errors(diagnostics: Iterable[Diagnostic]) -> bool:
    """True if any diagnostic is error-severity."""
    return any(d.severity >= Severity.ERROR for d in diagnostics)


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered analyzer rule: stable code, family, short title, body."""

    code: str
    family: str
    title: str
    fn: Callable[["_Graph"], Iterable[Diagnostic]]


RULES: dict[str, Rule] = {}


def rule(code: str, family: str, title: str):
    """Class the decorated generator as the rule body for ``code``."""
    def deco(fn):
        if code in RULES:  # pragma: no cover - registry misuse guard
            raise ValueError(f"duplicate rule code {code}")
        RULES[code] = Rule(code=code, family=family, title=title, fn=fn)
        return fn
    return deco


class _Graph:
    """Precomputed views of one Application that all rules share."""

    def __init__(self, app: Application, taps: Iterable[str] = ()):
        self.app = app
        self.taps = set(taps)
        self.aus = {a.name: a for a in app.analytics_units}
        self.drivers = {d.name: d for d in app.drivers}
        self.actuators = {a.name: a for a in app.actuators}
        self.streams = {s.name: s for s in app.streams}
        self.sensors = {s.name: s for s in app.sensors}
        self.consumers = consumer_counts(app)
        # subject -> streams that consume it
        self.consuming_streams: dict[str, list[StreamSpec]] = {}
        for s in app.streams:
            for i in s.inputs:
                self.consuming_streams.setdefault(i, []).append(s)
        # subject -> gadgets that consume it
        self.consuming_gadgets: dict[str, list] = {}
        for g in app.gadgets:
            for i in g.inputs:
                self.consuming_gadgets.setdefault(i, []).append(g)
        # subject -> producer output schema (sensors via driver, streams via AU)
        self.producer_schema: dict[str, StreamSchema] = {}
        for sensor in app.sensors:
            drv = self.drivers.get(sensor.driver)
            if drv is not None:
                self.producer_schema[sensor.name] = drv.output_schema
        for s in app.streams:
            au = self.aus.get(s.analytics_unit)
            if au is not None:
                self.producer_schema[s.name] = au.output_schema
        self.declared = set(self.sensors) | set(self.streams)
        self.durable = ({n for n, s in self.sensors.items() if s.durable}
                        | {n for n, s in self.streams.items() if s.durable})

    def au_of(self, spec: StreamSpec) -> AnalyticsUnitSpec | None:
        return self.aus.get(spec.analytics_unit)

    def pool_ceiling(self, spec: StreamSpec) -> int:
        """Largest instance count this stream's pool can reach: the fixed
        count if pinned, else the AU's autoscale ceiling."""
        if spec.fixed_instances is not None:
            return spec.fixed_instances
        au = self.au_of(spec)
        return au.max_instances if au is not None else 1

    def input_schema_for(self, consumer: StreamSpec,
                         subject: str) -> StreamSchema | None:
        """The consumer AU's declared schema for the edge from ``subject``
        (positional), or None when undeclared."""
        au = self.au_of(consumer)
        if au is None or subject not in consumer.inputs:
            return None
        idx = list(consumer.inputs).index(subject)
        schemas = list(au.input_schemas)
        if idx < len(schemas):
            return schemas[idx]
        return schemas[0] if len(schemas) == 1 else None


# ---------------------------------------------------------------------------
# DX1xx — ordering / exactly-once hazards
# ---------------------------------------------------------------------------

@rule("DX101", "ordering", "stateful stage under non-keyed delivery")
def _rule_stateful_delivery(g: _Graph) -> Iterator[Diagnostic]:
    for s in g.app.streams:
        au = g.au_of(s)
        if au is None or not au.stateful or s.delivery == "keyed":
            continue
        if au.combinator in ("reduce", "window"):
            yield Diagnostic(
                "DX101", Severity.ERROR, f"stream/{s.name}",
                f"per-key stateful {au.combinator!r} stage runs under "
                f"{s.delivery!r} delivery; its KeyedStore state is only "
                f"consistent when every key sticks to one instance",
                fixit="route it keyed: .key_by(field) upstream of the "
                      f".{au.combinator}(...)")
        elif s.delivery == "group" and g.pool_ceiling(s) > 1:
            yield Diagnostic(
                "DX101", Severity.WARNING, f"stream/{s.name}",
                f"stateful AU {au.name!r} runs as a plain group pool that "
                f"can reach {g.pool_ceiling(s)} instances sharing one "
                f"platform database; concurrent updates from round-robin "
                f"members race",
                fixit="key the stream (.key_by) or pin it: "
                      ".scaled(instances=1)")


@rule("DX102", "ordering", "broadcast into a stateful pool duplicates state")
def _rule_broadcast_stateful(g: _Graph) -> Iterator[Diagnostic]:
    for s in g.app.streams:
        au = g.au_of(s)
        if au is None or s.delivery != "broadcast" or not au.stateful:
            continue
        if g.pool_ceiling(s) > 1:
            yield Diagnostic(
                "DX102", Severity.ERROR, f"stream/{s.name}",
                f"broadcast delivery hands EVERY message to each of up to "
                f"{g.pool_ceiling(s)} instances of stateful AU {au.name!r}, "
                f"which share one platform database — every update is "
                f"applied once per instance",
                fixit="use group/keyed delivery, or pin the pool: "
                      ".scaled(instances=1)")


@rule("DX103", "ordering", "work stealing feeding an order-sensitive stage")
def _rule_steal_ordering(g: _Graph) -> Iterator[Diagnostic]:
    for s in g.app.streams:
        if not s.steal:
            continue
        if s.delivery == "broadcast":
            yield Diagnostic(
                "DX103", Severity.ERROR, f"stream/{s.name}",
                "steal=True on a broadcast stream: there is no queue group "
                "to steal from (every instance already sees every message)",
                fixit="drop steal=True or switch to group/keyed delivery")
            continue
        if s.delivery != "group":
            continue  # keyed stealing migrates whole partitions: order-safe
        for t in g.consuming_streams.get(s.name, ()):
            t_au = g.au_of(t)
            sensitive = (t.delivery == "keyed"
                         or (t_au is not None and t_au.stateful))
            if sensitive:
                what = ("keyed consumer" if t.delivery == "keyed"
                        else "stateful consumer")
                yield Diagnostic(
                    "DX103", Severity.ERROR, f"stream/{s.name}",
                    f"steal=True on plain-group stream {s.name!r} perturbs "
                    f"publish order across the pool, but downstream "
                    f"{what} {t.name!r} depends on arrival order",
                    fixit="key the pool (.key_by makes stealing "
                          "partition-granular and order-safe) or drop "
                          "steal=True")


@rule("DX104", "ordering", "replay from a non-durable subject")
def _rule_replay_durability(g: _Graph) -> Iterator[Diagnostic]:
    for s in g.app.streams:
        if s.replay_from is None:
            continue
        for subject in s.inputs:
            if subject in g.declared and subject not in g.durable:
                yield Diagnostic(
                    "DX104", Severity.ERROR, f"stream/{s.name}",
                    f"replay_from={s.replay_from!r} but input subject "
                    f"{subject!r} is not durable — there is no log to "
                    f"replay; the stream would start empty",
                    fixit=f"mark the producer durable: "
                          f"{subject!r}.durable(retention=...)")


@rule("DX105", "ordering", "keyed stream whose key the producer drops")
def _rule_key_dropped(g: _Graph) -> Iterator[Diagnostic]:
    for s in g.app.streams:
        if s.delivery != "keyed" or not s.key:
            continue
        for subject in s.inputs:
            schema = g.producer_schema.get(subject)
            if schema is None or not schema.fields:
                continue  # external or untyped producer: unknowable here
            if s.key not in schema.fields:
                yield Diagnostic(
                    "DX105", Severity.ERROR, f"stream/{s.name}",
                    f"keyed on field {s.key!r} but the producer of input "
                    f"{subject!r} declares schema fields "
                    f"{sorted(schema.fields)} — the key is dropped "
                    f"upstream, so every message would hash on a missing "
                    f"field",
                    fixit=f"carry {s.key!r} through the upstream schema, "
                          f"or key on a field the producer emits")


# ---------------------------------------------------------------------------
# DX2xx — fusion explainability
# ---------------------------------------------------------------------------

@rule("DX201", "fusion", "why an adjacent DEVICE chain did not fuse")
def _rule_fusion_explain(g: _Graph) -> Iterator[Diagnostic]:
    segments = plan_segments(g.app, taps=g.taps)
    seg_of: dict[str, int] = {}
    for i, seg in enumerate(segments):
        for s in seg:
            seg_of[s.name] = i
    for down in g.app.streams:
        d_au = g.au_of(down)
        if d_au is None or d_au.placement is not Placement.DEVICE \
                or d_au.fused_stages:
            continue
        for subject in down.inputs:
            up = g.streams.get(subject)
            if up is None:
                continue
            u_au = g.au_of(up)
            if u_au is None or u_au.placement is not Placement.DEVICE \
                    or u_au.fused_stages:
                continue
            if seg_of.get(up.name) is not None \
                    and seg_of.get(up.name) == seg_of.get(down.name):
                continue  # fused together — nothing to explain
            reason = stream_barrier(up, g.aus)
            if reason is None:
                reason = edge_barrier(up, down, g.aus,
                                      consumers=g.consumers, taps=g.taps)
            if reason is None:  # pragma: no cover - planner disagreement
                continue
            yield Diagnostic(
                "DX201", Severity.INFO, f"stream/{down.name}",
                f"DEVICE chain {up.name!r} -> {down.name!r} did not fuse: "
                f"{reason.name} — {reason.explain}",
                fixit="see docs/diagnostics.md#dx201 for how each barrier "
                      "is lifted")


# ---------------------------------------------------------------------------
# DX3xx — mesh / sharding / batching
# ---------------------------------------------------------------------------

def _schemas_with_nodes(g: _Graph) -> Iterator[tuple[str, StreamSchema]]:
    for d in g.app.drivers:
        yield f"driver/{d.name}", d.output_schema
    for a in g.app.analytics_units:
        yield f"au/{a.name}", a.output_schema
        for i, sch in enumerate(a.input_schemas):
            yield f"au/{a.name}#in{i}", sch
    for a in g.app.actuators:
        for i, sch in enumerate(a.input_schemas):
            yield f"actuator/{a.name}#in{i}", sch


@rule("DX301", "sharding", "ShardSpec that cannot address its field")
def _rule_shard_shape(g: _Graph) -> Iterator[Diagnostic]:
    for node, schema in _schemas_with_nodes(g):
        for fname, f in (schema.fields or {}).items():
            if f.sharding is None:
                continue
            axes = tuple(f.sharding.axes)
            named = [a for a in axes if a is not None]
            if f.shape is not None and len(axes) != len(f.shape):
                yield Diagnostic(
                    "DX301", Severity.ERROR, f"field/{node}.{fname}",
                    f"sharding names {len(axes)} dims {axes!r} but the "
                    f"field's shape {tuple(f.shape)!r} has "
                    f"{len(f.shape)} — the hint can never address the "
                    f"array",
                    fixit="give ShardSpec exactly one entry (axis name or "
                          "None) per array dimension")
            if len(named) != len(set(named)):
                dupes = sorted({a for a in named if named.count(a) > 1})
                yield Diagnostic(
                    "DX301", Severity.ERROR, f"field/{node}.{fname}",
                    f"sharding {axes!r} names mesh axis(es) {dupes} more "
                    f"than once; an axis can split at most one dimension",
                    fixit="replicate the extra dimension (None) or use a "
                          "different mesh axis")


@rule("DX302", "sharding", "axis named on a dimension it can never divide")
def _rule_shard_divisibility(g: _Graph) -> Iterator[Diagnostic]:
    for node, schema in _schemas_with_nodes(g):
        for fname, f in (schema.fields or {}).items():
            if f.sharding is None or f.shape is None:
                continue
            axes = tuple(f.sharding.axes)
            for dim, axis in zip(f.shape, axes):
                if axis is not None and dim == 1:
                    yield Diagnostic(
                        "DX302", Severity.WARNING, f"field/{node}.{fname}",
                        f"mesh axis {axis!r} is named on a size-1 "
                        f"dimension of shape {tuple(f.shape)!r}; no mesh "
                        f"larger than 1 can ever divide it, so the hint "
                        f"silently degrades to replication",
                        fixit="replicate that dimension (None) or shard a "
                              "dimension with extent > 1")


@rule("DX303", "sharding", "conflicting max_batch declarations in a segment")
def _rule_max_batch_conflict(g: _Graph) -> Iterator[Diagnostic]:
    for seg in plan_segments(g.app, taps=g.taps):
        declared = [(s.name, s.max_batch) for s in seg
                    if s.max_batch is not None]
        if len({b for _, b in declared}) <= 1:
            continue
        winner_name, winner = declared[-1]
        losers = [f"{n}={b}" for n, b in declared[:-1] if b != winner]
        yield Diagnostic(
            "DX303", Severity.WARNING, f"stream/{winner_name}",
            f"fused segment {seg[0].name!r}..{seg[-1].name!r} has "
            f"conflicting max_batch declarations ({', '.join(losers)} vs "
            f"{winner_name}={winner}); the stage closest to the exit wins "
            f"and {winner} silently overrides the rest",
            fixit="declare max_batch on one stage of the chain, or make "
                  "the declarations agree")


# ---------------------------------------------------------------------------
# DX4xx — hygiene
# ---------------------------------------------------------------------------

@rule("DX401", "hygiene", "dead stream: produced but never consumed")
def _rule_dead_stream(g: _Graph) -> Iterator[Diagnostic]:
    for name in sorted(g.declared):
        spec = g.streams.get(name) or g.sensors.get(name)
        kind = "stream" if name in g.streams else "sensor"
        if g.consumers.get(name, 0) > 0 or name in g.taps:
            continue
        if getattr(spec, "durable", False):
            continue  # durable = retained history is the consumer contract
        yield Diagnostic(
            "DX401", Severity.WARNING, f"{kind}/{name}",
            f"{kind} {name!r} has no consumer stream or gadget, is not "
            f".tap()-promised to external subscribers, and is not durable "
            f"— every message it publishes is dropped on the floor",
            fixit="feed it to a consumer, promise it (.tap()), make it "
                  ".durable(), or delete it")


@rule("DX402", "hygiene", "legacy deprecated spelling used statically")
def _rule_legacy_spellings(g: _Graph) -> Iterator[Diagnostic]:
    for node, schema in _schemas_with_nodes(g):
        for fname, f in (schema.fields or {}).items():
            if f.sharding is not None and getattr(f.sharding, "legacy",
                                                  False):
                yield Diagnostic(
                    "DX402", Severity.WARNING, f"field/{node}.{fname}",
                    f"sharding hint {tuple(f.sharding.axes)!r} was spelled "
                    f"as a legacy bare tuple (deprecated since the typed "
                    f"API landed; warns once per call site at runtime)",
                    fixit=f"spell it "
                          f"ShardSpec({tuple(f.sharding.axes)!r})")


@rule("DX403", "hygiene", "retention declared without durability")
def _rule_retention_without_durable(g: _Graph) -> Iterator[Diagnostic]:
    specs = [("sensor", s) for s in g.app.sensors] \
        + [("stream", s) for s in g.app.streams]
    for kind, s in specs:
        if s.retention is not None and not s.durable:
            yield Diagnostic(
                "DX403", Severity.ERROR, f"{kind}/{s.name}",
                f"{kind} {s.name!r} declares retention {dict(s.retention)!r} "
                f"but is not durable — there is no log for the retention "
                f"policy to bound",
                fixit="mark it .durable(retention=...) or drop the "
                      "retention")


@rule("DX404", "hygiene", "schema field produced but never consumed")
def _rule_unconsumed_field(g: _Graph) -> Iterator[Diagnostic]:
    for subject, schema in g.producer_schema.items():
        if not schema.fields:
            continue
        if subject in g.taps or subject in g.durable:
            continue  # promised externally — consumption is unknowable
        consumers = g.consuming_streams.get(subject, [])
        gadgets = g.consuming_gadgets.get(subject, [])
        if not consumers and not gadgets:
            continue  # DX401 territory
        needed: set[str] = set()
        for t in consumers:
            sch = g.input_schema_for(t, subject)
            if sch is None or not sch.fields:
                needed = set(schema.fields)  # untyped consumer: uses anything
                break
            needed |= set(sch.fields)
            if t.delivery == "keyed" and t.key:
                needed.add(t.key)
        else:
            for gd in gadgets:
                act = g.actuators.get(gd.actuator)
                schemas = list(act.input_schemas) if act is not None else []
                idx = list(gd.inputs).index(subject)
                sch = schemas[idx] if idx < len(schemas) else (
                    schemas[0] if len(schemas) == 1 else None)
                if sch is None or not sch.fields:
                    needed = set(schema.fields)
                    break
                needed |= set(sch.fields)
        for fname in sorted(set(schema.fields) - needed):
            yield Diagnostic(
                "DX404", Severity.INFO, f"field/{subject}.{fname}",
                f"field {fname!r} of {subject!r} is produced but no typed "
                f"consumer schema mentions it — it is serialized, "
                f"published, and dropped on every message",
                fixit="consume it downstream or drop it from the producer "
                      "schema")


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def analyze_application(app: Application, *, taps: Iterable[str] = (),
                        ignores: Iterable[str] = ()) -> list[Diagnostic]:
    """Run every registered rule over a compiled Application.

    ``taps`` are the subjects promised to external subscribers (the DSL's
    ``App.build`` passes its ``.tap()`` set); ``ignores`` suppresses codes
    (the CLI fills it from ``# datax: ignore[DXnnn]`` pragmas).  Returns
    diagnostics in stable (rule-code, graph) order, each stamped with
    ``app.name``.
    """
    g = _Graph(app, taps=taps)
    ignores = set(ignores)
    out: list[Diagnostic] = []
    for code in sorted(RULES):
        if code in ignores:
            continue
        for d in RULES[code].fn(g):
            if d.code not in ignores:
                out.append(dataclasses.replace(d, app=app.name))
    return out


def analyze_target(obj: Any) -> list[tuple[str, Application, frozenset]]:
    """Coerce a check target into ``(label, application, taps)`` triples.

    Accepts a compiled v1 :class:`Application`, a v2 fluent ``App`` (duck-
    typed on ``_compile``/``_taps`` so this module never imports the DSL),
    or a zero-argument callable returning either.
    """
    if isinstance(obj, Application):
        return [(obj.name, obj, frozenset())]
    if hasattr(obj, "_compile") and hasattr(obj, "_taps"):
        return [(obj.name, obj._compile(), frozenset(obj._taps))]
    if callable(obj):
        return analyze_target(obj())
    raise TypeError(
        f"cannot analyze {type(obj).__name__!r}: expected an Application, "
        f"a fluent App, or a zero-argument callable returning one")


# ---------------------------------------------------------------------------
# CLI (python -m repro.core.analyze / tools/datax_check.py)
# ---------------------------------------------------------------------------

_PRAGMA = re.compile(r"#\s*datax:\s*ignore\[([A-Z]{2}\d{3})\]")


def scan_ignores(source: str) -> set[str]:
    """Codes suppressed by ``# datax: ignore[DXnnn] <reason>`` pragmas."""
    return set(_PRAGMA.findall(source))


def _load_module(target: str):
    """Resolve ``pkg.mod[:attr]`` or ``path/to/file.py[:attr]``."""
    modpart, _, attr = target.partition(":")
    if modpart.endswith(".py") or "/" in modpart:
        path = Path(modpart)
        # script-style semantics: the file's directory joins sys.path so the
        # target can import its siblings (fixtures' shared helpers etc.)
        parent = str(path.resolve().parent)
        if parent not in sys.path:
            sys.path.insert(0, parent)
        spec = importlib.util.spec_from_file_location(path.stem, path)
        if spec is None or spec.loader is None:
            raise ImportError(f"cannot load {modpart!r}")
        module = importlib.util.module_from_spec(spec)
        sys.modules.setdefault(path.stem, module)
        spec.loader.exec_module(module)
    else:
        module = importlib.import_module(modpart)
    return module, (attr or None)


def _discover(module) -> list[tuple[str, Any]]:
    """Find checkable apps in a module: ``build_app``/``*_app`` zero-arg
    callables first, else module-level App/Application objects."""
    found: list[tuple[str, Any]] = []
    for name in sorted(vars(module)):
        if name.startswith("_"):
            continue
        obj = getattr(module, name)
        if callable(obj) and (name == "build_app" or name.endswith("_app")):
            try:
                params = [
                    p for p in inspect.signature(obj).parameters.values()
                    if p.default is p.empty
                    and p.kind not in (p.VAR_POSITIONAL, p.VAR_KEYWORD)]
            except (TypeError, ValueError):
                continue
            if not params:
                found.append((name, obj))
    if found:
        return found
    for name in sorted(vars(module)):
        obj = getattr(module, name)
        if isinstance(obj, Application) \
                or (hasattr(obj, "_compile") and hasattr(obj, "_taps")):
            found.append((name, obj))
    return found


def main(argv: Iterable[str] | None = None) -> int:
    """CLI entry point; returns the process exit code (1 on errors found)."""
    parser = argparse.ArgumentParser(
        prog="datax check",
        description="Static dataflow analysis of a DataX app graph.")
    parser.add_argument(
        "target",
        help="module[:attr] or path/to/file.py[:attr]; without :attr, "
             "checks every zero-arg *_app/build_app factory (or module-"
             "level app object) found in the module")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="emit a JSON report instead of text")
    args = parser.parse_args(list(argv) if argv is not None else None)

    module, attr = _load_module(args.target)
    source = ""
    if getattr(module, "__file__", None):
        try:
            source = Path(module.__file__).read_text()
        except OSError:  # pragma: no cover - unreadable module file
            source = ""
    ignores = scan_ignores(source)

    if attr is not None:
        targets = [(attr, getattr(module, attr))]
    else:
        targets = _discover(module)
    if not targets:
        print(f"datax check: no app found in {args.target!r} "
              f"(expected a zero-arg *_app factory or a module-level app)",
              file=sys.stderr)
        return 2

    reports: list[dict] = []
    diagnostics: list[Diagnostic] = []
    for label, obj in targets:
        for app_label, application, taps in analyze_target(obj):
            diags = analyze_application(application, taps=taps,
                                        ignores=ignores)
            diagnostics.extend(diags)
            reports.append({
                "target": f"{args.target}:{label}", "app": app_label,
                "diagnostics": [d.to_json() for d in diags]})

    errors = [d for d in diagnostics if d.severity >= Severity.ERROR]
    if args.as_json:
        print(json.dumps({"reports": reports, "errors": len(errors),
                          "ignored_codes": sorted(ignores)}, indent=2))
    else:
        for rep in reports:
            print(f"== {rep['app']} ({rep['target']}) ==")
            if not rep["diagnostics"]:
                print("  clean")
            for d in rep["diagnostics"]:
                fix = f"  [fix: {d['fixit']}]" if d["fixit"] else ""
                print(f"  {d['code']} {d['severity']:<7} {d['node']}: "
                      f"{d['message']}{fix}")
        summary = (f"datax check: {len(diagnostics)} diagnostic(s), "
                   f"{len(errors)} error(s)")
        if ignores:
            summary += f" (ignoring {', '.join(sorted(ignores))})"
        print(summary)
    return 1 if errors else 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    sys.exit(main())
