"""Typed delivery, replay, and addressing API (the subscribe/connect surface).

Subscription behaviour used to be spelled as loose kwargs — ``group=``,
``key=``, ``partitions=``, ``replay_from=`` on ``subscribe()`` and
``serve=``/``remote=``/``peer=`` unions on :func:`~.dsl.connect`.  This module
gives each concept one small value type:

* :class:`DeliveryPolicy` — how a subject's messages reach a set of
  subscribers: :class:`Broadcast` (every subscriber sees every message),
  :class:`Group` (named single-delivery worker pool), :class:`Keyed` (a
  group whose messages are rendezvous-hashed on a payload field so each key
  sticks to one member).
* :class:`ReplayFrom` — where a subscription on a durable subject starts in
  the retained log before flipping to live delivery.
* :class:`Listen` / :class:`Peer` — the two sides of a cross-process
  attachment: expose this operator's bus over TCP, or join another host's.

The old kwarg spellings keep working everywhere they did before — each call
site gets a single :class:`DeprecationWarning` (python's default warning
filter de-duplicates per call site) and is mapped onto these types by
:func:`resolve_policy` / :func:`resolve_replay`, so the runtime only ever
sees the typed form.
"""
from __future__ import annotations

import dataclasses
import warnings

#: Default number of hash partitions per keyed group.  Partitions, not
#: members, are the unit of assignment: keys map to partitions permanently
#: (stable hash), and only the partition->member mapping changes on
#: membership churn.  64 keeps the rendezvous spread within ~25% of fair for
#: small pools while the assignment map stays cheap to snapshot.
KEYED_PARTITIONS = 64


# ---------------------------------------------------------------------------
# Delivery policies
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeliveryPolicy:
    """Base class of the typed delivery policies accepted by ``subscribe()``.

    Concrete policies: :class:`Broadcast`, :class:`Group`, :class:`Keyed`.
    A policy is a pure value — it fully determines the legacy
    ``(group, key, partitions)`` triple via :meth:`legacy_args`, which is
    what the bus layers consume internally.
    """

    def legacy_args(self) -> tuple:
        """The ``(group, key, partitions)`` triple this policy denotes."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Broadcast(DeliveryPolicy):
    """Every subscriber receives every message (the bus default).

    Equivalent to subscribing with no group at all; scaled instances under
    broadcast are *replicas* (redundant/speculative execution), not a pool.
    """

    def legacy_args(self) -> tuple:
        """``(None, None, None)`` — no group, no key."""
        return (None, None, None)


@dataclasses.dataclass(frozen=True)
class Group(DeliveryPolicy):
    """Named single-delivery queue group (NATS-style worker pool).

    All subscriptions sharing ``name`` on a subject form one pool: each
    message reaches exactly one healthy member, departing members re-home
    their backlog to survivors.

    ``steal=True`` additionally lets an idle member pull queued work from
    the deepest healthy member's mailbox tail (pull-based work stealing) —
    a straggler's share no longer waits behind it.  The first member to
    join with ``steal=True`` enables it for the whole pool.
    """

    name: str
    steal: bool = False

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("Group needs a non-empty name")

    def legacy_args(self) -> tuple:
        """``(name, None, None)`` — plain queue-group delivery."""
        return (self.name, None, None)


@dataclasses.dataclass(frozen=True)
class Keyed(DeliveryPolicy):
    """Keyed single delivery: hash ``field`` onto a partition ring.

    A :class:`Group` upgraded so every message whose payload ``field``
    hashes to a given partition reaches the same member — stateful stages
    scale without splitting a key's state.  ``partitions`` fixes the ring
    size at group creation (all members must agree).

    ``steal=True`` enables partition-granular work stealing: an idle member
    takes *whole* queued partitions (never interleaving a key) from the
    deepest member, so per-key ordering survives the migration.
    """

    group: str
    field: str
    partitions: int = KEYED_PARTITIONS
    steal: bool = False

    def __post_init__(self) -> None:
        if not self.group:
            raise ValueError("Keyed needs a non-empty group name")
        if not self.field:
            raise ValueError("Keyed needs the payload field to hash")
        if self.partitions < 1:
            raise ValueError(f"Keyed needs partitions >= 1, "
                             f"got {self.partitions}")

    def legacy_args(self) -> tuple:
        """``(group, field, partitions)`` — keyed-ring delivery."""
        return (self.group, self.field, self.partitions)


# ---------------------------------------------------------------------------
# Replay start positions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplayFrom:
    """Typed start position in a durable subject's log.

    Wraps the raw replay vocabulary (``int`` offset / ``float`` timestamp /
    ``"earliest"`` / ``"snapshot"``) the durability layer resolves; build
    one with :meth:`offset`, :meth:`timestamp`, :meth:`earliest` or
    :meth:`snapshot`.
    """

    start: object

    @staticmethod
    def offset(n: int) -> "ReplayFrom":
        """Start at log offset ``n`` (the ``n``-th appended record)."""
        return ReplayFrom(int(n))

    @staticmethod
    def timestamp(ts: float) -> "ReplayFrom":
        """Start at the first record appended at-or-after wall time ``ts``."""
        return ReplayFrom(float(ts))

    @staticmethod
    def earliest() -> "ReplayFrom":
        """Start at the oldest retained offset."""
        return ReplayFrom("earliest")

    @staticmethod
    def snapshot() -> "ReplayFrom":
        """Start at the newest exactly-once recovery watermark (resolved
        against the stream's state database at spawn time)."""
        return ReplayFrom("snapshot")


# ---------------------------------------------------------------------------
# Cross-process addressing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Listen:
    """TCP listen address for exposing an operator's bus over the wire.

    ``connect(listen=Listen())`` binds an ephemeral port on localhost; read
    the bound address from ``op.bus_address``.
    """

    host: str = "127.0.0.1"
    port: int = 0


@dataclasses.dataclass(frozen=True)
class Peer:
    """Attachment address of an EXISTING deployment's bus server.

    ``connect(peer=Peer("host:port", name="edge-1"))`` joins the remote bus
    as a first-class member; ``name`` identifies this process in the host's
    per-peer transport metrics (pick a stable one for keyed recovery).
    """

    address: str
    name: str = ""

    def __post_init__(self) -> None:
        if not self.address:
            raise ValueError("Peer needs a 'host:port' address")


# ---------------------------------------------------------------------------
# Legacy-kwarg shims
# ---------------------------------------------------------------------------

def _warn(message: str, stacklevel: int) -> None:
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel + 1)


def policy_from_legacy(group: str | None, key: str | None,
                       partitions: int | None = None
                       ) -> DeliveryPolicy | None:
    """The typed policy a legacy ``(group, key, partitions)`` triple denotes
    (None for plain broadcast).  Used by runtime layers that carry the triple
    internally — no deprecation note."""
    if key is not None:
        return Keyed(group or "", key,
                     partitions if partitions is not None else KEYED_PARTITIONS)
    if group is not None:
        return Group(group)
    return None


def resolve_policy(policy: DeliveryPolicy | None,
                   group: str | None, key: str | None,
                   partitions: int | None, *,
                   stacklevel: int = 3) -> tuple:
    """Canonical ``(group, key, partitions)`` from a policy OR legacy kwargs.

    Exactly one spelling may be used; the legacy one warns (once per call
    site under the default warning filter).  ``stacklevel`` should point the
    warning at the caller of the subscribing API, not at this helper.
    """
    legacy = (group is not None or key is not None or partitions is not None)
    if policy is not None:
        if legacy:
            raise TypeError(
                "pass either policy= or the legacy group=/key=/partitions= "
                "kwargs, not both")
        if not isinstance(policy, DeliveryPolicy):
            raise TypeError(f"policy must be a DeliveryPolicy "
                            f"(Broadcast/Group/Keyed), got "
                            f"{type(policy).__name__}")
        g, k, p = policy.legacy_args()
        return (g, k, p if p is not None else KEYED_PARTITIONS)
    if legacy:
        if key is not None:
            repl = (f"Keyed({group!r}, {key!r}"
                    + (f", partitions={partitions}"
                       if partitions is not None else "") + ")")
        elif group is not None:
            repl = f"Group({group!r})"
        else:
            repl = "Keyed(..., partitions=...)"
        _warn(f"subscribe(group=/key=/partitions=) is deprecated; pass "
              f"policy={repl}", stacklevel)
    return (group, key,
            partitions if partitions is not None else KEYED_PARTITIONS)


def resolve_replay(replay: ReplayFrom | None, replay_from,
                   *, stacklevel: int = 3):
    """Canonical raw replay value from ``replay=ReplayFrom(...)`` OR the
    legacy ``replay_from=`` kwarg (which warns once per call site)."""
    if replay is not None:
        if replay_from is not None:
            raise TypeError("pass either replay= or the legacy replay_from= "
                            "kwarg, not both")
        if not isinstance(replay, ReplayFrom):
            raise TypeError(f"replay must be a ReplayFrom, got "
                            f"{type(replay).__name__}")
        return replay.start
    if replay_from is not None:
        if isinstance(replay_from, ReplayFrom):
            # tolerate the typed value under the old kwarg, silently
            return replay_from.start
        _warn("replay_from= is deprecated; pass replay=ReplayFrom.offset(n) "
              "/ .timestamp(ts) / .earliest() / .snapshot()", stacklevel)
    return replay_from
