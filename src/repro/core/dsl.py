"""Fluent typed Stream API (v2) — decorators + combinators over the v1 specs.

The v1 surface (``entities.py`` + ``app.py``) is faithful to the paper's CRDs
but verbose: seven parallel ``*Spec`` dataclasses and imperative
``op.register_*`` calls.  This module is the productivity layer on top:

* **entity declaration by decorator** — ``@app.driver``, ``@app.analytics_unit``,
  ``@app.actuator``.  The config schema is inferred from the factory's keyword
  defaults (``def thermometer(ctx, n=200)`` ⇒ ``n: int = 200``); the output
  stream schema comes from a ``StreamSchema`` return annotation or an explicit
  ``emits=`` argument.
* **topology by combinator** — ``app.sense(...)`` returns a typed
  :class:`StreamHandle` supporting ``.map`` / ``.filter`` / ``.window`` /
  ``.via`` / :meth:`StreamHandle.fuse` and ``>> gadget``.  Combinator lambdas
  are wrapped into synthetic :class:`~.entities.AnalyticsUnitSpec`\\ s, so a
  v2 app is observable/upgradeable exactly like a v1 app.
* **eager schema checking** — every edge is checked at composition time
  (consumer's declared input schema must *accept* the producer's schema), so
  a type error surfaces at the line that wires the streams, not at deploy.
* **keyed streams** — ``.key_by(field)`` partitions the stream by a payload
  field: downstream stages compile to keyed-delivery streams (same key ->
  same instance, in order), per-key stateful combinators (``.reduce``,
  ``.window(..., per_key=True)``) keep their state in the stream's platform
  database, and ``.scaled()`` therefore scales *stateful* stages too —
  partition rebalances hand state over instead of losing it.
* **device placement + chain fusion** — ``.map(fn, device=True)`` /
  ``.filter(pred, device=True)`` declare pure array stages; at :meth:`App.build`
  the chain-fusion pass (:mod:`~.fusion`) collapses maximal linear DEVICE
  chains into one fused unit (a single jitted program on accelerator
  backends) with zero interior bus hops.  ``.tap()`` pins a stream to the
  bus; ``.via(au, upgrade=...)`` re-composes config upgrades to
  ``op.upgrade_analytics_unit`` at deploy.

Everything compiles deterministically into the existing
:class:`~.app.Application` spec graph and deploys via ``Application.deploy``;
coherence rules, autoscaling, upgrades and the bus are untouched.

Quickstart::

    app = App("quickstart")

    @app.driver(emits=READING)
    def thermometer(ctx, n=200):
        ...

    @app.analytics_unit(expects=(READING,), emits=SCORE)
    def anomaly(ctx):
        ...

    @app.actuator(expects=(SCORE,))
    def alarm(ctx, threshold=4.0):
        ...

    scores = app.sense("lab-temp", thermometer, n=200).via(anomaly,
                                                           name="anomalies")
    scores >> app.gadget("siren", alarm)

    with connect() as op:
        app.deploy(op)
"""
from __future__ import annotations

import contextlib
import dataclasses
import inspect
import logging
import time
import warnings
from collections import deque
from typing import Any, Callable, Iterator, Mapping, Sequence

from .app import Application, AppValidationError
from .delivery import Listen, Peer, ReplayFrom, resolve_replay
from .durable import DurableError, Retention
from .entities import (ActuatorSpec, AnalyticsUnitSpec, DatabaseSpec,
                       DriverSpec, GadgetSpec, Placement, SensorSpec,
                       StreamSpec)
from .fusion import fuse_application, mesh_axis_names
from .operator import Operator
from .schema import KNOWN_MESH_AXES, ConfigSchema, StreamSchema
from .state import KeyedStore

#: Non-strict builds log error/warning diagnostics from ``datax check``
#: through the analyzer's logger (named after the module that owns the
#: rules, so ``logging.getLogger("repro.core.analyze")`` filters them).
_analyze_logger = logging.getLogger("repro.core.analyze")


class DSLError(AppValidationError):
    """Bad v2 composition (unknown entity, name clash, wrong argument)."""


class SchemaMismatch(DSLError):
    """An edge's producer schema violates the consumer's declared schema."""


# ---------------------------------------------------------------------------
# Inference helpers
# ---------------------------------------------------------------------------

_TYPE_NAMES = {bool: "bool", int: "int", float: "float", str: "str",
               bytes: "bytes", dict: "dict", list: "list"}


def _type_name(value: Any) -> str:
    # bool first: bool is a subclass of int
    for pytype, name in _TYPE_NAMES.items():
        if type(value) is pytype:
            return name
    return "any"


def _annotation_type_name(annotation: Any) -> str:
    if annotation in _TYPE_NAMES:
        return _TYPE_NAMES[annotation]
    if isinstance(annotation, str) and annotation in _TYPE_NAMES.values():
        return annotation
    return "any"


def _infer_config_schema(fn: Callable) -> tuple[ConfigSchema, tuple[str, ...]]:
    """Config schema from the factory's parameters after ``ctx``.

    ``def thermometer(ctx, n=200)`` ⇒ ``{n: ("int", 200)}``; a parameter with
    no default becomes a REQUIRED field (type taken from its annotation).
    Returns (schema, parameter-names) so the runtime wrapper knows which
    resolved config keys to pass back as keyword arguments.
    """
    params = list(inspect.signature(fn).parameters.values())
    if not params:
        raise DSLError(f"{fn.__name__}: entity factories take (ctx, ...)")
    fields: dict[str, tuple] = {}
    names: list[str] = []
    for p in params[1:]:
        if p.kind in (inspect.Parameter.VAR_POSITIONAL,
                      inspect.Parameter.VAR_KEYWORD):
            continue
        names.append(p.name)
        if p.default is inspect.Parameter.empty:
            fields[p.name] = (_annotation_type_name(p.annotation),
                              ConfigSchema.REQUIRED)
        else:
            fields[p.name] = (_type_name(p.default), p.default)
    return ConfigSchema(fields=fields), tuple(names)


def _infer_output_schema(fn: Callable, emits: StreamSchema | None) -> StreamSchema:
    if emits is not None:
        return emits
    ann = getattr(fn, "__annotations__", {}).get("return")
    if isinstance(ann, str):
        # PEP 563 (`from __future__ import annotations` in the user's module)
        # stringifies the annotation; resolve it against the factory's globals
        try:
            ann = eval(ann, getattr(fn, "__globals__", {}))  # noqa: S307
        except Exception:
            ann = None
    if isinstance(ann, StreamSchema):
        return ann
    return StreamSchema.untyped()


def _wrap_factory(fn: Callable, config_params: Sequence[str]) -> Callable:
    """Adapt ``fn(ctx, **config)`` to the runtime's ``logic(ctx)`` contract."""
    def logic(ctx):
        cfg = {k: v for k, v in ctx.config.items() if k in config_params}
        return fn(ctx, **cfg)
    logic.__name__ = fn.__name__
    logic.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
    return logic


def _logic_and_schema(fn: Callable,
                      config: ConfigSchema | None) -> tuple[Callable, ConfigSchema]:
    """Runtime logic + config schema for a decorated factory.

    SDK-style entrypoints (``@sdk_entrypoint``) own their loop and read config
    via ``dx.get_configuration()`` — they pass through unwrapped (declare their
    schema with ``config=`` if any).
    """
    if getattr(fn, "datax_sdk_style", False):
        return fn, config or ConfigSchema.empty()
    inferred, params = _infer_config_schema(fn)
    return _wrap_factory(fn, params), config or inferred


def _check_edge(consumer: str, declared: Sequence[StreamSchema], index: int,
                producer: "StreamHandle") -> None:
    if index < len(declared) and not declared[index].accepts(producer.schema):
        raise SchemaMismatch(
            f"{consumer!r} input {index} cannot accept stream "
            f"{producer.name!r}: producer schema "
            f"{sorted(producer.schema.fields) or '<untyped>'} does not satisfy "
            f"the declared input schema {sorted(declared[index].fields)}")


def _shared_key(handles: Sequence["StreamHandle"]) -> str | None:
    """The common partition key of a set of input handles (None if they are
    unkeyed or disagree — a multi-input stage cannot partition two ways)."""
    keys = {h.key for h in handles}
    return keys.pop() if len(keys) == 1 else None


def _key_through(key: str | None, schema: StreamSchema) -> str | None:
    """The key survives a stage only while its output schema still (or may
    still) carry the field — a typed schema without it ends the keyed chain
    explicitly instead of silently hashing a missing field."""
    if key is None:
        return None
    return key if (not schema.fields or key in schema.fields) else None


def _entity_name(ref: Any) -> str:
    """Resolve a decorated function (or plain string) to its entity name."""
    if isinstance(ref, str):
        return ref
    name = getattr(ref, "_datax_entity", None)
    if name is None:
        raise DSLError(f"{ref!r} is not a registered entity; decorate it with "
                       f"@app.driver / @app.analytics_unit / @app.actuator "
                       f"or pass the entity name")
    return name


# ---------------------------------------------------------------------------
# Stream handles
# ---------------------------------------------------------------------------

class StreamHandle:
    """A typed reference to one registered stream inside an :class:`App`.

    Handles are cheap, immutable descriptors: every combinator appends specs
    to the owning app and returns a *new* handle for the derived stream.
    ``key`` is the partition field declared by :meth:`key_by` (None =
    unkeyed): combinators on a keyed handle compile to keyed-delivery
    streams, and the per-key stateful combinators (:meth:`reduce`,
    ``window(per_key=True)``) require it.
    """

    def __init__(self, app: "App", name: str, schema: StreamSchema,
                 key: str | None = None):
        self.app = app
        self.name = name
        self.schema = schema
        self.key = key

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"StreamHandle({self.name!r})"

    # -- keyed streams --------------------------------------------------------
    def key_by(self, field: str) -> "StreamHandle":
        """Declare ``field`` as this stream's partition key (§3 scaling for
        *stateful* consumers).

        Downstream combinators compile to ``delivery="keyed"`` streams: the
        platform hashes ``field`` onto a stable partition ring so every
        message for a key is processed by the same instance, in order — which
        is what makes scaled stateful stages (``.reduce``,
        ``.window(per_key=True)``, stateful ``.via`` AUs) safe.  Scale
        events re-home whole partitions to survivors (ordered hand-off), and
        per-key state lives in the stream's shared platform database, so a
        rebalance finds its state instead of losing it.
        """
        if self.schema.fields and field not in self.schema.fields:
            raise DSLError(
                f"key_by({field!r}): stream {self.name!r} has no such field; "
                f"schema fields are {sorted(self.schema.fields)}")
        return StreamHandle(self.app, self.name, self.schema, key=field)

    # -- routing through declared AUs ---------------------------------------
    def via(self, au: Any, *, name: str | None = None,
            fixed_instances: int | None = None,
            upgrade: bool | Callable[[dict], dict] | None = None,
            **config: Any) -> "StreamHandle":
        """Route this stream through a decorator-registered analytics unit.

        ``upgrade`` opts this AU into upgrade-in-place at deploy time: if the
        target operator already runs an older version of the AU, the deploy
        re-composes to ``op.upgrade_analytics_unit`` (cascading to running
        streams, §4) instead of failing the registration.  Pass ``True`` for a
        schema-compatible upgrade, or a converter ``old_config -> new_config``
        for incompatible ones (accepted only if it succeeds for every running
        instance).
        """
        handle = self.app._compose_stream((self,), au, name=name,
                                          fixed_instances=fixed_instances,
                                          config=config)
        if upgrade:
            self.app._upgrades[_entity_name(au)] = \
                None if upgrade is True else upgrade
        return handle

    def tap(self) -> "StreamHandle":
        """Promise this stream to external subscribers (§3 reuse).

        A tapped stream always stays a bus subject: the fusion pass treats it
        as a segment barrier instead of folding it into a device program.
        """
        self.app._taps.add(self.name)
        return self

    # -- durability -----------------------------------------------------------
    def durable(self, *, retention: Mapping[str, Any] | None = None
                ) -> "StreamHandle":
        """Attach an append-only log to this stream's subject.

        Every published message is retained (codec-tagged, rolling segments)
        and late consumers can :meth:`replay` the history — the subject's
        past survives consumer churn and crashes.  ``retention`` bounds the
        log with any of ``max_records`` / ``max_age_s`` / ``max_bytes``
        (whole sealed segments are evicted oldest-first once a limit is
        exceeded; omitted = unbounded).

        Works on sensor streams (corpus/event sources) and derived streams
        alike.  A durable stream always stays a bus subject — the fusion
        pass treats it as a segment barrier rather than folding its subject
        away into a device program.
        """
        try:
            Retention.of(retention)          # fail at the wiring line
        except DurableError as e:
            raise DSLError(f"stream {self.name!r}: {e}") from e
        for i, s in enumerate(self.app._sensors):
            if s.name == self.name:
                self.app._sensors[i] = dataclasses.replace(
                    s, durable=True, retention=retention)
                return self
        index = next((i for i, s in enumerate(self.app._streams)
                      if s.name == self.name), None)
        if index is None:
            raise DSLError(
                f"{self.name!r} is not a stream of app {self.app.name!r}; "
                f"external streams are made durable by their owning app")
        self.app._streams[index] = dataclasses.replace(
            self.app._streams[index], durable=True, retention=retention)
        return self

    def replay(self, *, from_: Any = "earliest") -> "StreamHandle":
        """Start this stream's instances on their inputs' durable logs.

        ``from_`` is an int log offset, a float unix timestamp,
        ``"earliest"`` (the oldest retained record), or ``"snapshot"`` —
        resolved at spawn time against the stream's platform database to
        the suffix after the last exactly-once recovery watermark (the
        crash-recovery mode for keyed stateful stages).  History is served
        first, then the subscription switches to live delivery with no gap
        and no duplicate at the handoff.

        Every input subject must be durable (:meth:`durable` upstream);
        inputs owned by other apps are checked at deploy by the operator.
        """
        if isinstance(from_, bool) or not (
                isinstance(from_, (int, float))
                or from_ in ("earliest", "snapshot")):
            raise DSLError(
                f"replay(from_={from_!r}): expected an int offset, a float "
                f"timestamp, 'earliest' or 'snapshot'")
        index = next((i for i, s in enumerate(self.app._streams)
                      if s.name == self.name), None)
        if index is None:
            raise DSLError(
                f"{self.name!r} is not a derived stream of app "
                f"{self.app.name!r}; .replay() configures where a stream's "
                f"instances START on their inputs — sensors have no inputs "
                f"(use op.subscribe(..., replay_from=...) for external "
                f"subscribers)")
        spec = self.app._streams[index]
        durable_here = ({s.name for s in self.app._sensors if s.durable}
                        | {s.name for s in self.app._streams if s.durable})
        declared = ({s.name for s in self.app._sensors}
                    | {s.name for s in self.app._streams})
        missing = [i for i in spec.inputs
                   if i in declared and i not in durable_here]
        if missing:
            raise DSLError(
                f"stream {self.name!r}: .replay() needs durable inputs, but "
                f"{missing} are not durable — mark them with "
                f".durable(retention=...) first")
        self.app._streams[index] = dataclasses.replace(spec,
                                                       replay_from=from_)
        return self

    def scaled(self, *, delivery: str | None = None,
               instances: int | None = None,
               max_instances: int | None = None,
               max_batch: int | None = None,
               steal: bool | None = None) -> "StreamHandle":
        """Scaling & delivery escape hatch for this stream's instances.

        ``delivery="group"`` (the platform default) makes scaled instances a
        single-delivery worker pool: they join one bus queue group per input
        subject and each message reaches exactly one of them.
        ``delivery="broadcast"`` restores replica semantics — every instance
        receives every message (redundant/speculative execution).
        ``delivery=None`` keeps the stream's current policy — in particular
        a keyed stream (built downstream of :meth:`key_by`) stays keyed.

        ``instances`` fixes the pool size (the operator will not autoscale
        it); ``max_instances`` instead lets the operator autoscale a
        combinator stage between 1 and the given ceiling — group delivery
        makes that safe for stateless ``.map``/``.filter`` stages, which were
        pinned single-instance before queue groups existed.  Stateful
        combinators scale too **when keyed**: under keyed delivery every key
        sticks to one instance and per-key state lives in the stream's
        platform database, so ``.reduce`` / ``.window(per_key=True)`` pools
        stay exactly-once per key with no state races.  Unkeyed stateful
        combinators (``.window``, ``fuse``) keep their per-instance buffers
        and stay single-instance, as do broadcast combinator stages (scaling
        those would duplicate messages downstream).

        ``max_batch`` bounds batched execution for batching-capable units
        (fused DEVICE chains): under backlog each mailbox pull drains up to
        ``max_batch`` queued messages into ONE vmapped device program call
        instead of dispatching per message.  Deeper bursts raise throughput
        under load but can add tail latency for the last message of a burst;
        ``max_batch=1`` forces per-message dispatch.  A shallow mailbox
        always falls back to single-message pulls, so idle latency is
        unaffected either way.  On a device chain, declare it on any stage —
        fusion folds it onto the fused unit; if several stages declare one,
        the stage closest to the segment exit wins.

        ``steal=True`` opts the pool into pull-based work stealing: an idle
        member pulls queued work from the deepest sibling's mailbox, so one
        straggler can't pin its share of the backlog.  Under keyed delivery
        stealing migrates whole partitions (per-key order preserved); under
        plain group delivery individual messages move, which perturbs
        arrival order across the pool — ``datax check`` flags that (DX103)
        when a downstream stage is order-sensitive.  Meaningless (and
        rejected) for broadcast streams.
        """
        if delivery is not None and delivery not in ("group", "broadcast"):
            raise DSLError(f"delivery must be 'group' or 'broadcast', "
                           f"got {delivery!r} (keyed delivery is declared "
                           f"with .key_by(field), not .scaled())")
        if instances is not None and instances < 1:
            raise DSLError(f"instances must be >= 1, got {instances}")
        if max_instances is not None and max_instances < 1:
            raise DSLError(f"max_instances must be >= 1, got {max_instances}")
        if max_batch is not None and max_batch < 1:
            raise DSLError(f"max_batch must be >= 1, got {max_batch}")
        index = next((i for i, s in enumerate(self.app._streams)
                      if s.name == self.name), None)
        if index is None:
            raise DSLError(
                f"{self.name!r} is not a derived stream of app "
                f"{self.app.name!r}; sensors run exactly one driver instance "
                f"and external streams are scaled by their owning app")
        spec = self.app._streams[index]
        au = self.app._aus[spec.analytics_unit]
        keyed = spec.delivery == "keyed"
        if keyed and delivery is not None:
            raise DSLError(
                f"stream {self.name!r} is keyed on {spec.key!r}; "
                f".scaled(delivery={delivery!r}) would discard the key "
                f"policy — re-compose without .key_by() instead")
        resolved = delivery if delivery is not None else spec.delivery
        # guards judge the pool configuration this call RESULTS in, not just
        # its own arguments — a prior .scaled() may already have fixed a pool
        # size or lifted the combinator's autoscale envelope
        if instances is not None:
            fixed = instances
        elif max_instances is not None:
            fixed = None                      # autoscale pool
        else:
            fixed = spec.fixed_instances
        ceiling = max(instances or 1, max_instances or 1,
                      au.max_instances if au.combinator else 1)
        pool = fixed if fixed is not None else ceiling
        if steal and resolved == "broadcast":
            raise DSLError(
                f"stream {self.name!r}: steal=True needs a queue group to "
                f"steal from; broadcast instances each see every message "
                f"already")
        if au.combinator and pool > 1:
            if au.combinator not in ("map", "filter") and not keyed:
                raise DSLError(
                    f"stream {self.name!r}: a .{au.combinator} stage keeps "
                    f"per-instance state and cannot scale past one "
                    f"instance; partition it with .key_by(field) to scale "
                    f"stateful stages")
            if resolved == "broadcast":
                raise DSLError(
                    f"stream {self.name!r}: broadcast replicas of a "
                    f".{au.combinator} stage would emit every message "
                    f"{pool}x downstream; use delivery='group'")
        if au.combinator:
            # synthetic AUs are 1:1 with their stream — lift the declared
            # instance envelope so create_stream/autoscaler can use it
            self.app._aus[au.name] = dataclasses.replace(
                au, max_instances=max(ceiling, au.max_instances))
        elif max_instances is not None:
            raise DSLError(
                f"stream {self.name!r}: the autoscale ceiling of declared "
                f"analytics unit {au.name!r} is set on its declaration "
                f"(@app.analytics_unit(max_instances=...)); .scaled() only "
                f"fixes the pool size via instances=")
        self.app._streams[index] = dataclasses.replace(
            spec, delivery=resolved, fixed_instances=fixed,
            max_batch=max_batch if max_batch is not None else spec.max_batch,
            steal=steal if steal is not None else spec.steal)
        return self

    # -- combinators (synthetic AUs) ----------------------------------------
    def map(self, fn: Callable[[dict], Any], *, name: str | None = None,
            emits: StreamSchema | None = None,
            device: bool = False) -> "StreamHandle":
        """Transform each payload with ``fn(payload) -> payload | None``.

        The output schema is ``emits`` if given (checked against downstream
        consumers), else untyped — an untyped stream cannot feed a consumer
        that declares a typed input schema, so supply ``emits=`` at the last
        combinator before a typed edge.

        ``device=True`` declares ``fn`` a *pure array transform* and places
        the stage on the mesh: at build time, maximal chains of device stages
        are fused into a single jitted program with no interior bus hops
        (``fn`` must be traceable — numeric payload fields, no side effects;
        untraceable stages fall back to per-stage host execution).
        """
        def factory(ctx):
            return lambda stream, payload: fn(payload)
        factory.__name__ = getattr(fn, "__name__", "map")
        return self.app._synthetic_stream(
            (self,), factory, kind="map", name=name,
            emits=_infer_output_schema(fn, emits),
            placement=Placement.DEVICE if device else Placement.HOST,
            pure_fn=fn if device else None, key=self.key)

    def reduce(self, fn: Callable[[Any, dict], Any], *, init: Any = None,
               name: str | None = None,
               emits: StreamSchema | None = None,
               ttl: float | None = None, max_keys: int | None = None,
               snapshot_every: int = 64) -> "StreamHandle":
        """Per-key running reduction: for each payload emit
        ``{<key_field>: k, "value": fn(acc, payload)}`` where ``acc`` is the
        key's previous accumulator (``init`` the first time).

        Requires :meth:`key_by` upstream — the accumulator lives in the
        stream's platform database (:class:`~.state.KeyedStore`), not in the
        instance, so the stage scales with ``.scaled()``: keyed delivery
        pins each key to one instance (exactly-once, in-order folds) and a
        scale event re-homes a partition's keys to an instance that reads
        the same store — no state is lost or forked.

        ``ttl`` / ``max_keys`` bound the store for long-tail key spaces
        (seconds of idle before a key's accumulator expires / oldest-written
        eviction above the cap).

        On a durable input the fold is **exactly-once through crashes**:
        each update is applied via :meth:`~.state.KeyedStore.apply_once`
        keyed by the message's durable-log offset, so a recovery replay
        (``.replay(from_="snapshot")``) re-delivers history without
        double-applying or re-emitting anything already folded in.  Every
        ``snapshot_every`` applied updates the instance records a recovery
        watermark (:meth:`~.state.KeyedStore.snapshot`), bounding how much
        log a restarted member has to replay.
        """
        if self.key is None:
            raise DSLError(
                f"stream {self.name!r}: .reduce() is a per-key combinator; "
                f"declare the partition field with .key_by(field) first")
        if snapshot_every < 1:
            raise DSLError(
                f"snapshot_every must be >= 1, got {snapshot_every}")
        field = self.key

        def factory(ctx):
            store = KeyedStore(ctx.db, "reduce", ttl=ttl, max_keys=max_keys)
            stats = {"snapshots": 0, "last_snapshot_ts": None}
            # watermark = highest durable-log offset this instance applied;
            # since_snapshot counts applied updates since the last watermark
            state = {"watermark": -1, "since_snapshot": 0}

            def process(stream, payload, headers=None):
                k = payload.get(field)
                offset = (headers or {}).get("offset")
                acc, applied = store.apply_once(
                    k, offset, lambda prev: fn(prev, payload), init=init)
                if not applied:
                    # this log position is already folded into the store
                    # (recovery replay overlapping live delivery, or a
                    # rebalance racing a recovery): emitting again would
                    # duplicate downstream — exactly-once means skipping
                    # the side effect too
                    return None
                if offset is not None:
                    if offset > state["watermark"]:
                        state["watermark"] = offset
                    state["since_snapshot"] += 1
                    if state["since_snapshot"] >= snapshot_every:
                        store.snapshot(ctx.instance_id, state["watermark"])
                        state["since_snapshot"] = 0
                        stats["snapshots"] += 1
                        stats["last_snapshot_ts"] = time.time()
                return {field: k, "value": acc}
            process.wants_headers = True
            process.stats = stats
            return process
        factory.__name__ = getattr(fn, "__name__", "reduce")
        out_schema = emits or StreamSchema.untyped()
        return self.app._synthetic_stream(
            (self,), factory, kind="reduce", name=name, emits=out_schema,
            stateful=True, key=field)

    def filter(self, pred: Callable[[dict], bool], *,
               name: str | None = None, device: bool = False) -> "StreamHandle":
        """Keep only payloads where ``pred(payload)`` is truthy.

        Filtering never changes the message type, so the output schema is the
        input schema (the one combinator with exact schema propagation).
        ``device=True`` fuses the predicate into the surrounding device chain
        (predicated execution: stages still run, the keep flag gates emission).
        """
        def factory(ctx):
            return lambda stream, payload: payload if pred(payload) else None
        factory.__name__ = getattr(pred, "__name__", "filter")
        return self.app._synthetic_stream(
            (self,), factory, kind="filter", name=name, emits=self.schema,
            placement=Placement.DEVICE if device else Placement.HOST,
            pure_fn=pred if device else None, key=self.key)

    def window(self, n: int, *, name: str | None = None,
               emits: StreamSchema | None = None,
               per_key: bool = False) -> "StreamHandle":
        """Tumbling count window: every ``n`` payloads emit
        ``{"window": [...], "count": n}``.

        ``per_key=True`` windows each key separately (requires
        :meth:`key_by` upstream) and adds the key field to the emitted
        payload.  The per-key buffers live in the stream's platform database
        (:class:`~.state.KeyedStore`) rather than an instance-local list, so
        the stage scales with ``.scaled()`` and survives partition
        rebalances without dropping half-filled windows.
        """
        if n < 1:
            raise DSLError(f"window size must be >= 1, got {n}")
        if per_key:
            if self.key is None:
                raise DSLError(
                    f"stream {self.name!r}: window(per_key=True) needs the "
                    f"partition field; declare it with .key_by(field) first")
            field = self.key

            def keyed_factory(ctx):
                store = KeyedStore(ctx.db, f"window{n}")

                def process(stream, payload):
                    k = payload.get(field)
                    buf = store.get(k, []) + [payload]
                    if len(buf) < n:
                        store.put(k, buf)
                        return None
                    store.put(k, [])
                    return {field: k, "window": buf, "count": len(buf)}
                return process
            keyed_factory.__name__ = f"window{n}_by_{field}"
            return self.app._synthetic_stream(
                (self,), keyed_factory, kind="window", name=name,
                emits=emits or StreamSchema.untyped(),
                stateful=True, key=field)

        def factory(ctx):
            buf: list[dict] = []

            def process(stream, payload):
                buf.append(payload)
                if len(buf) < n:
                    return None
                out = {"window": list(buf), "count": len(buf)}
                buf.clear()
                return out
            return process
        factory.__name__ = f"window{n}"
        return self.app._synthetic_stream(
            (self,), factory, kind="window", name=name,
            emits=emits or StreamSchema.untyped())

    @staticmethod
    def fuse(*handles: "StreamHandle", with_: Any, name: str | None = None,
             emits: StreamSchema | None = None,
             fixed_instances: int | None = None,
             **config: Any) -> "StreamHandle":
        """Fuse two or more streams into one.

        ``with_`` is either a decorator-registered analytics unit (the stream
        is routed through it, v1-style multi-input) or a plain callable
        ``fn(payload_a, payload_b, ...) -> payload`` that is called with one
        aligned payload per input stream (FIFO pairing).
        """
        if len(handles) < 2:
            raise DSLError("fuse() needs at least two streams")
        names = [h.name for h in handles]
        if len(set(names)) != len(names):
            # the pairing buffer is keyed by stream name; a self-join would
            # collapse to one deque and crash on the first aligned pop
            raise DSLError(f"fuse() streams must be distinct, got {names}; "
                           f"self-joins need an intermediate .map/.via stream")
        apps = {h.app for h in handles}
        if len(apps) != 1:
            raise DSLError("fuse() streams must belong to the same App")
        app = handles[0].app
        if getattr(with_, "_datax_entity", None) or isinstance(with_, str):
            if emits is not None:
                raise DSLError(
                    "fuse(emits=...) only applies to a plain callable; a "
                    "registered AU's output schema comes from its declaration")
            return app._compose_stream(handles, with_, name=name,
                                       fixed_instances=fixed_instances,
                                       config=config)
        if not callable(with_):
            raise DSLError("with_ must be a registered AU or a callable")
        if config:
            raise DSLError(
                f"fuse() config kwargs {sorted(config)} only apply when "
                f"with_ is a registered AU; a plain callable takes no config")
        if fixed_instances not in (None, 1):
            raise DSLError(
                "a plain-callable fuse runs single-instance (its pairing "
                "buffer is per-instance); fixed_instances must be 1")

        inputs = tuple(h.name for h in handles)

        def factory(ctx):
            # bounded like every other platform queue: if one input stalls or
            # lags, the other's backlog drops oldest instead of growing
            # without limit (streams are lossy real-time flows)
            buf: dict[str, deque] = {s: deque(maxlen=256) for s in inputs}

            def process(stream, payload):
                buf[stream].append(payload)
                if all(buf.values()):
                    return with_(*(buf[s].popleft() for s in inputs))
                return None
            return process
        factory.__name__ = getattr(with_, "__name__", "fuse")
        return app._synthetic_stream(
            handles, factory, kind="fuse", name=name,
            emits=_infer_output_schema(with_, emits))

    # -- sinks ---------------------------------------------------------------
    def __rshift__(self, gadget: "GadgetHandle") -> "GadgetHandle":
        """``stream >> gadget`` — feed this stream into a gadget."""
        if not isinstance(gadget, GadgetHandle):
            raise DSLError(f"stream >> expects a GadgetHandle "
                           f"(from app.gadget(...)), got {type(gadget).__name__}")
        gadget._attach(self)
        return gadget

    def subscribe(self, op: Operator, *, maxsize: int = 256,
                  policy: Any = None, replay: Any = None,
                  replay_from: Any = None):
        """Third-party subscription to this stream on a live operator (§3).

        ``policy`` (a typed :class:`~.delivery.DeliveryPolicy`) lets the
        consumer join under group/keyed delivery; broadcast by default.  On
        a durable stream, ``replay=ReplayFrom.offset(n)`` /
        ``.timestamp(ts)`` / ``.earliest()`` serves the retained history
        first, then switches to live delivery — late-joining consumers see
        the full past.  The deprecated raw ``replay_from=`` values keep
        working with a warning."""
        replay_value = resolve_replay(replay, replay_from)
        return op.subscribe(self.name, maxsize=maxsize, policy=policy,
                            replay=ReplayFrom(replay_value)
                            if replay_value is not None else None)


class GadgetHandle:
    """A declared gadget accumulating input streams via ``stream >> gadget``."""

    def __init__(self, app: "App", name: str, actuator: str,
                 config: Mapping[str, Any]):
        self.app = app
        self.name = name
        self.actuator = actuator
        self.config = dict(config)
        self.inputs: list[str] = []

    def _attach(self, handle: StreamHandle) -> None:
        decl = self.app._actuators[self.actuator]
        _check_edge(f"gadget {self.name!r} (actuator {self.actuator!r})",
                    decl.input_schemas, len(self.inputs), handle)
        self.inputs.append(handle.name)


# ---------------------------------------------------------------------------
# The App
# ---------------------------------------------------------------------------

class App:
    """The v2 application builder: decorators + stream combinators.

    Compiles (deterministically, in declaration/composition order) into a v1
    :class:`~.app.Application` via :meth:`build`; :meth:`deploy` is
    ``build().deploy(op)`` — the Operator, coherence rules and bus are
    exactly the v1 ones.
    """

    def __init__(self, name: str):
        self.name = name
        self._drivers: dict[str, DriverSpec] = {}
        self._aus: dict[str, AnalyticsUnitSpec] = {}
        self._actuators: dict[str, ActuatorSpec] = {}
        self._sensors: list[SensorSpec] = []
        self._streams: list[StreamSpec] = []
        self._gadgets: list[GadgetHandle] = []
        self._databases: list[DatabaseSpec] = []
        self._stream_names: set[str] = set()
        self._synthetic_aus = 0
        self._taps: set[str] = set()
        self._upgrades: dict[str, Callable[[dict], dict] | None] = {}

    # ================================================================ decl
    def driver(self, fn: Callable | None = None, *, name: str | None = None,
               emits: StreamSchema | None = None,
               config: ConfigSchema | None = None, version: int = 1,
               node_affinity: str | None = None):
        """Declare a driver.  The factory is ``fn(ctx, **config)`` returning
        an iterator (or poll callable) of payload dicts."""
        def deco(f: Callable) -> Callable:
            ename = name or f.__name__
            logic, schema = _logic_and_schema(f, config)
            spec = DriverSpec(
                name=ename, logic=logic, config_schema=schema,
                output_schema=_infer_output_schema(f, emits),
                version=version, node_affinity=node_affinity)
            self._register(self._drivers, spec, "driver")
            f._datax_entity = ename
            return f
        return deco(fn) if callable(fn) else deco

    def analytics_unit(self, fn: Callable | None = None, *,
                       name: str | None = None,
                       expects: Sequence[StreamSchema] = (),
                       emits: StreamSchema | None = None,
                       config: ConfigSchema | None = None, version: int = 1,
                       placement: Placement = Placement.HOST,
                       stateful: bool = False, min_instances: int = 1,
                       max_instances: int = 8):
        """Declare an analytics unit.  The factory is ``fn(ctx, **config)``
        returning ``process(stream, payload) -> payload | list | None``."""
        def deco(f: Callable) -> Callable:
            ename = name or f.__name__
            logic, schema = _logic_and_schema(f, config)
            spec = AnalyticsUnitSpec(
                name=ename, logic=logic, config_schema=schema,
                input_schemas=tuple(expects),
                output_schema=_infer_output_schema(f, emits),
                version=version, placement=placement, stateful=stateful,
                min_instances=min_instances, max_instances=max_instances)
            self._register(self._aus, spec, "analytics unit")
            f._datax_entity = ename
            return f
        return deco(fn) if callable(fn) else deco

    def actuator(self, fn: Callable | None = None, *, name: str | None = None,
                 expects: Sequence[StreamSchema] = (),
                 config: ConfigSchema | None = None, version: int = 1):
        """Declare an actuator.  The factory is ``fn(ctx, **config)``
        returning a sink ``process(stream, payload)``."""
        def deco(f: Callable) -> Callable:
            ename = name or f.__name__
            logic, schema = _logic_and_schema(f, config)
            spec = ActuatorSpec(
                name=ename, logic=logic, config_schema=schema,
                input_schemas=tuple(expects), version=version)
            self._register(self._actuators, spec, "actuator")
            f._datax_entity = ename
            return f
        return deco(fn) if callable(fn) else deco

    def _register(self, registry: dict, spec: Any, kind: str) -> None:
        if spec.name in registry:
            raise DSLError(f"{kind} {spec.name!r} already declared "
                           f"in app {self.name!r}")
        registry[spec.name] = spec

    # ================================================================ topo
    def sense(self, name: str, driver: Any, **config: Any) -> StreamHandle:
        """Register a sensor; returns the handle of its output stream."""
        dname = _entity_name(driver)
        if dname not in self._drivers:
            raise DSLError(f"driver {dname!r} is not declared in app "
                           f"{self.name!r}")
        spec = self._drivers[dname]
        spec.config_schema.validate(config)  # fail at the wiring line
        self._claim_stream_name(name)
        self._sensors.append(SensorSpec(name=name, driver=dname,
                                        config=config))
        return StreamHandle(self, name, spec.output_schema)

    def external(self, name: str,
                 schema: StreamSchema | None = None) -> StreamHandle:
        """Handle for a stream registered by *another* app on the target
        operator (the paper's §3 stream reuse).  ``schema`` is the caller's
        assumption about the producer; untyped if omitted."""
        return StreamHandle(self, name, schema or StreamSchema.untyped())

    def gadget(self, name: str, actuator: Any, **config: Any) -> GadgetHandle:
        """Declare a gadget; feed it streams with ``stream >> gadget``."""
        aname = _entity_name(actuator)
        if aname not in self._actuators:
            raise DSLError(f"actuator {aname!r} is not declared in app "
                           f"{self.name!r}")
        self._actuators[aname].config_schema.validate(config)
        if any(g.name == name for g in self._gadgets):
            raise DSLError(f"gadget {name!r} already declared")
        handle = GadgetHandle(self, name, aname, config)
        self._gadgets.append(handle)
        return handle

    def database(self, name: str, *, engine: str = "memkv",
                 tables: Mapping[str, Sequence[str]] | None = None) -> "App":
        """Declare a platform-managed database for this app's entities
        (``engine``: ``"memkv"`` or ``"filekv"``); instances reach it as
        ``ctx.db`` / ``dx.db``."""
        if any(d.name == name for d in self._databases):
            raise DSLError(f"database {name!r} already declared "
                           f"in app {self.name!r}")
        self._databases.append(DatabaseSpec(name=name, engine=engine,
                                            tables=dict(tables or {})))
        return self

    # -- stream creation (shared by .via / fuse / combinators) ---------------
    def _compose_stream(self, inputs: Sequence[StreamHandle], au: Any, *,
                        name: str | None = None,
                        fixed_instances: int | None = None,
                        config: Mapping[str, Any] | None = None) -> StreamHandle:
        aname = _entity_name(au)
        if aname not in self._aus:
            raise DSLError(f"analytics unit {aname!r} is not declared in app "
                           f"{self.name!r}")
        spec = self._aus[aname]
        for i, h in enumerate(inputs):
            _check_edge(f"analytics unit {aname!r}", spec.input_schemas, i, h)
        spec.config_schema.validate(dict(config or {}))
        sname = name or self._auto_name(inputs[0].name, aname)
        self._claim_stream_name(sname)
        key = _shared_key(inputs)
        self._streams.append(StreamSpec(
            name=sname, analytics_unit=aname,
            inputs=tuple(h.name for h in inputs),
            config=dict(config or {}), fixed_instances=fixed_instances,
            delivery="keyed" if key else "group", key=key))
        return StreamHandle(self, sname, spec.output_schema,
                            key=_key_through(key, spec.output_schema))

    def _synthetic_stream(self, inputs: Sequence[StreamHandle],
                          factory: Callable, *, kind: str, name: str | None,
                          emits: StreamSchema,
                          placement: Placement = Placement.HOST,
                          pure_fn: Callable | None = None,
                          stateful: bool = False,
                          key: str | None = None) -> StreamHandle:
        """Wrap a combinator lambda into a synthetic single-instance AU.

        ``key`` makes the combinator's stream keyed-delivery (set by
        combinators downstream of :meth:`StreamHandle.key_by`); ``stateful``
        marks per-key stateful combinators so the operator attaches the
        stream's shared platform database (their :class:`~.state.KeyedStore`
        home)."""
        sname = name or self._auto_name(inputs[0].name, kind)
        self._claim_stream_name(sname)
        au_name = f"{sname}.{kind}"
        au = AnalyticsUnitSpec(
            name=au_name, logic=factory,
            input_schemas=tuple(h.schema for h in inputs),
            output_schema=emits,
            # single-instance by default: combinators are often stateful
            # closures (window/fuse buffers).  Stateless map/filter stages —
            # and KEYED stateful ones, whose state is per-key in the platform
            # database — can opt into a worker pool via .scaled(), which
            # lifts this envelope; single/keyed delivery keeps exactly-once.
            min_instances=1, max_instances=1,
            placement=placement, pure_fn=pure_fn, combinator=kind,
            stateful=stateful)
        self._register(self._aus, au, "analytics unit")
        self._synthetic_aus += 1
        self._streams.append(StreamSpec(
            name=sname, analytics_unit=au_name,
            inputs=tuple(h.name for h in inputs), fixed_instances=1,
            delivery="keyed" if key else "group", key=key))
        return StreamHandle(self, sname, emits,
                            key=_key_through(key, emits))

    def _auto_name(self, base: str, kind: str) -> str:
        i = 0
        while f"{base}.{kind}{i}" in self._stream_names:
            i += 1
        return f"{base}.{kind}{i}"

    def _claim_stream_name(self, name: str) -> None:
        if name in self._stream_names:
            raise DSLError(f"stream/sensor name {name!r} already used "
                           f"in app {self.name!r}")
        self._stream_names.add(name)

    def _validate_sharding(self) -> None:
        """Check every device field's ShardSpec against the mesh axes."""
        allowed = set(KNOWN_MESH_AXES) | set(mesh_axis_names())
        schemas = []
        for d in self._drivers.values():
            schemas.append((f"driver {d.name!r}", d.output_schema))
        for a in self._aus.values():
            schemas.append((f"analytics_unit {a.name!r}", a.output_schema))
            schemas.extend((f"analytics_unit {a.name!r} input", s)
                           for s in a.input_schemas)
        for where, schema in schemas:
            if schema is None:
                continue
            for fname, spec in schema.sharding_hints().items():
                if spec is None:
                    continue
                try:
                    spec.validate_axes(allowed,
                                       where=f"{where} field {fname!r}")
                except ValueError as e:
                    raise DSLError(str(e)) from None

    # ================================================================ build
    def _compile(self) -> Application:
        """Compile to the UNFUSED v1 spec graph (deterministic: declaration
        order).  Shared by :meth:`build` and the ``datax check`` analyzer
        (:mod:`repro.core.analyze` duck-types on this + ``_taps``)."""
        self._validate_sharding()
        return Application(
            name=self.name,
            drivers=list(self._drivers.values()),
            analytics_units=list(self._aus.values()),
            actuators=list(self._actuators.values()),
            sensors=list(self._sensors),
            streams=list(self._streams),
            gadgets=[GadgetSpec(name=g.name, actuator=g.actuator,
                                inputs=tuple(g.inputs), config=g.config)
                     for g in self._gadgets],
            databases=list(self._databases),
            upgrades=dict(self._upgrades),
            taps=tuple(sorted(self._taps)),
        )

    def build(self, *, fuse: bool = True, strict: bool = False) -> Application:
        """Compile to the v1 spec graph (deterministic: declaration order).

        With ``fuse=True`` (default) the chain-fusion pass runs: maximal
        linear chains of DEVICE-placement stages collapse into single jitted
        units and their interior streams never reach the bus.  ``fuse=False``
        keeps every hop a bus subject (debugging / A-B benchmarking).

        Sharding hints are validated here: every device field's
        :class:`~.schema.ShardSpec` axis names must exist in the platform's
        mesh vocabulary (plus whatever axes the live device mesh actually
        has) — a typo'd axis fails the build, not a silent replicate at
        runtime.

        Every build also runs the ``datax check`` dataflow analyzer
        (:mod:`repro.core.analyze`) over the unfused graph: with
        ``strict=True`` any error-severity diagnostic raises
        :class:`~.analyze.DiagnosticsError`; the default ``strict=False``
        logs error/warning diagnostics through the ``repro.core.analyze``
        logger and builds anyway (info-severity findings are CLI-only).
        """
        from .analyze import (DiagnosticsError, Severity,
                              analyze_application, has_errors)
        application = self._compile()
        diagnostics = analyze_application(application,
                                          taps=frozenset(self._taps))
        if strict and has_errors(diagnostics):
            raise DiagnosticsError(diagnostics)
        for d in diagnostics:
            if d.severity >= Severity.WARNING:
                _analyze_logger.warning("%s", d.format())
        if fuse:
            application = fuse_application(application,
                                           taps=frozenset(self._taps))
        return application

    def deploy(self, op: Operator, *, start_sensors: bool = True,
               fuse: bool = True) -> Application:
        """Compile + validate + deploy onto a live operator; returns the
        compiled :class:`Application` (handy for undeploy/introspection).

        ``start_sensors=False`` defers the sources so external subscribers
        can attach first; fire them with ``op.start_pending_sensors()``.
        """
        application = self.build(fuse=fuse)
        application.deploy(op, start_sensors=start_sensors)
        return application

    def loc_footprint(self) -> int:
        """#entities in the compiled graph (v1-comparable productivity proxy)."""
        return self.build(fuse=False).loc_footprint()

    def declared_footprint(self) -> int:
        """#entities the *developer* wrote (synthetic combinator AUs excluded)
        — the number to quote for the paper's productivity claim."""
        return self.loc_footprint() - self._synthetic_aus


# ---------------------------------------------------------------------------
# Operator lifecycle
# ---------------------------------------------------------------------------

def _resolve_listen(listen: Listen | None,
                    serve: bool | int | tuple | None) -> Listen | None:
    """One Listen address from the typed kwarg OR the legacy ``serve=``
    union (bool / port / (host, port)), which warns once per call site."""
    if listen is not None:
        if serve is not None:
            raise DSLError("pass either listen=Listen(...) or the legacy "
                           "serve= kwarg, not both")
        if not isinstance(listen, Listen):
            raise DSLError(f"listen must be a Listen address, got "
                           f"{type(listen).__name__}")
        return listen
    if serve is None or serve is False:
        return None
    if serve is True:
        resolved = Listen()
    elif isinstance(serve, tuple):
        resolved = Listen(*serve)
    else:
        resolved = Listen(port=int(serve))
    warnings.warn(
        f"connect(serve=...) is deprecated; pass listen=Listen("
        f"{resolved.host!r}, {resolved.port})",
        DeprecationWarning, stacklevel=4)
    return resolved


def _resolve_peer(peer: str | Peer, remote: str | tuple | None
                  ) -> Peer | None:
    """One Peer address from the typed kwarg OR the legacy ``remote=`` +
    ``peer=<str name>`` pair, which warns once per call site."""
    if isinstance(peer, Peer):
        if remote is not None:
            raise DSLError("pass either peer=Peer(...) or the legacy "
                           "remote= kwarg, not both")
        return peer
    if remote is None:
        return None
    address = remote if isinstance(remote, str) \
        else f"{remote[0]}:{remote[1]}"
    warnings.warn(
        f"connect(remote=...) is deprecated; pass peer=Peer({address!r}"
        + (f", name={peer!r}" if peer else "") + ")",
        DeprecationWarning, stacklevel=4)
    return Peer(address, name=peer)


@contextlib.contextmanager
def connect(*, start: bool = True, listen: Listen | None = None,
            serve: bool | int | tuple | None = None,
            remote: str | tuple | None = None, peer: str | Peer = "",
            **operator_kwargs: Any) -> Iterator[Any]:
    """Context manager owning one process's attachment to a deployment.

    The default form owns a fresh in-process :class:`Operator`::

        with connect() as op:
            app.deploy(op)
            ...
        # reconciler stopped, instances torn down, bus closed

    ``start=False`` skips the reconcile loop (unit-test topologies that only
    need deploy + bus flow).  Extra kwargs go to :class:`Operator`.

    ``listen=Listen(host, port)`` additionally exposes the operator's bus
    over TCP — read the bound address from ``op.bus_address`` — so other
    processes can join.

    ``peer=Peer("host:port", name="edge-1")`` attaches to an EXISTING
    deployment instead of creating one: yields a
    :class:`~.serverless.RemoteWorker` whose instances run in this process
    but subscribe/publish over the wire as first-class queue-group /
    keyed-ring members (``name`` identifies this process in the host's
    per-peer transport metrics).  Mutually exclusive with ``listen`` and
    operator kwargs.

    The pre-dataclass spellings — ``serve=True|port|(host, port)`` and
    ``remote="host:port", peer="name"`` — keep working and map onto
    :class:`~.delivery.Listen` / :class:`~.delivery.Peer` with a
    :class:`DeprecationWarning` per call site.
    """
    attach = _resolve_peer(peer, remote)
    if attach is not None:
        if listen is not None or serve is not None or operator_kwargs:
            raise DSLError("connect(peer=...) attaches to an existing "
                           "deployment: listen=/serve=/Operator kwargs do "
                           "not apply")
        from .serverless import RemoteWorker
        worker = RemoteWorker(attach.address, peer=attach.name)
        try:
            yield worker
        finally:
            worker.close()
        return
    bind = _resolve_listen(listen, serve)
    op = Operator(**operator_kwargs)
    if bind is not None:
        op.serve(bind.host, bind.port)
    if start:
        op.start()
    try:
        yield op
    finally:
        op.shutdown()
