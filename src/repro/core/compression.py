"""Codec-tagged blob compression with graceful degradation.

The platform persists state (``state.py`` filekv databases) and training
checkpoints (``train/checkpoint.py``) as compressed msgpack blobs.  zstd is
the preferred codec, but it is a third-party dependency; on clean
environments the hard import used to break *all* of ``repro.core`` at
collection time.  This module makes ``zstandard`` optional:

* every blob is prefixed with a 4-byte codec tag (``b"DXZ1"`` = zstd,
  ``b"DXL1"`` = stdlib zlib, ``b"DXZ2"`` = zstd with a trained dictionary)
  so readers dispatch on what was actually written, regardless of what is
  importable today;
* writers pick zstd when available, else zlib — both are self-describing;
* legacy untagged blobs (raw zstd frames, magic ``28 B5 2F FD``) written
  before tagging existed are still readable when zstd is installed.

**Trained dictionaries** (:func:`train_dictionary`) close zstd's gap on
*small* payloads: a generic compressor has nothing to reference inside a
100-byte message, but a dictionary trained on the first N payloads of a
subject carries the stream's shared structure (field names, common values),
so subsequent blobs compress far below the no-dictionary floor.  Dictionary
blobs get their own tag (``DXZ2``) and are NOT self-describing — the reader
must supply the same dictionary bytes, which is why the durable log stores
the trained dictionary alongside its segments.  On the zlib leg
:func:`train_dictionary` returns ``None`` and writers fall back to plain
tagged blobs: degradation, not failure.
"""
from __future__ import annotations

import zlib

try:
    import zstandard
    HAS_ZSTD = True
except ImportError:  # clean environment: fall back to stdlib
    zstandard = None  # type: ignore[assignment]
    HAS_ZSTD = False

TAG_ZSTD = b"DXZ1"
TAG_ZLIB = b"DXL1"
TAG_ZSTD_DICT = b"DXZ2"
_ZSTD_FRAME_MAGIC = b"\x28\xb5\x2f\xfd"  # legacy untagged blobs


class CompressionError(RuntimeError):
    pass


def train_dictionary(samples: list[bytes], *,
                     max_size: int = 4096) -> bytes | None:
    """Train a zstd dictionary from sample payloads; ``None`` = no dictionary.

    Returns ``None`` (write plain tagged blobs instead) when zstd is not
    installed, when there are too few samples to train from, or when training
    itself fails (zstd refuses degenerate sample sets, e.g. all-identical
    bytes) — callers degrade gracefully rather than branching on the codec.
    """
    if not HAS_ZSTD or len(samples) < 8:
        return None
    try:
        d = zstandard.train_dictionary(max_size, list(samples))
        return d.as_bytes()
    except Exception:
        return None


def available_codecs() -> list[str]:
    """Codecs this process can read and write, preferred first.

    The wire-compression negotiation (``transport.py`` hello exchange)
    advertises this list; the serving side picks the first common entry.
    zlib is always last — every peer has it, so negotiation can only fail
    on a malformed hello, never on codec availability.
    """
    return ["zstd", "zlib"] if HAS_ZSTD else ["zlib"]


def compress(data: bytes, *, level: int = 3,
             dictionary: bytes | None = None,
             codec: str | None = None) -> bytes:
    """Compress ``data`` with the best available codec; returns a tagged blob.

    ``dictionary`` (bytes from :func:`train_dictionary`) switches the zstd
    leg to dictionary compression (tag ``DXZ2``); the zlib leg ignores it
    (plain ``DXL1`` blobs stay self-describing).

    ``codec`` pins the codec instead of auto-selecting: ``"zlib"`` forces a
    ``DXL1`` blob even when zstd is installed (a negotiated-down wire
    connection must never emit a tag the peer cannot read), ``"zstd"``
    requires zstd and raises :class:`CompressionError` without it.
    """
    if codec == "zlib":
        return TAG_ZLIB + zlib.compress(data, level)
    if codec == "zstd" and not HAS_ZSTD:
        raise CompressionError(
            "codec 'zstd' requested but the 'zstandard' module is not "
            "installed")
    if codec not in (None, "zstd", "zlib"):
        raise CompressionError(f"unknown codec {codec!r}")
    if HAS_ZSTD:
        if dictionary is not None:
            zd = zstandard.ZstdCompressionDict(dictionary)
            return TAG_ZSTD_DICT + zstandard.ZstdCompressor(
                level=level, dict_data=zd).compress(data)
        return TAG_ZSTD + zstandard.ZstdCompressor(level=level).compress(data)
    return TAG_ZLIB + zlib.compress(data, level)


def decompress(blob: bytes, *, dictionary: bytes | None = None) -> bytes:
    """Inverse of :func:`compress`; dispatches on the codec tag.

    ``DXZ2`` (dictionary) blobs require the same ``dictionary`` bytes they
    were written with — a missing/mismatched dictionary raises
    :class:`CompressionError` instead of returning garbage.
    """
    tag = blob[:4]
    if tag == TAG_ZLIB:
        return zlib.decompress(blob[4:])
    if tag == TAG_ZSTD:
        if not HAS_ZSTD:
            raise CompressionError(
                "blob was written with zstd but the 'zstandard' module is "
                "not installed; install it to read this data")
        return zstandard.ZstdDecompressor().decompress(blob[4:])
    if tag == TAG_ZSTD_DICT:
        if not HAS_ZSTD:
            raise CompressionError(
                "dictionary blob was written with zstd but the 'zstandard' "
                "module is not installed")
        if dictionary is None:
            raise CompressionError(
                "blob was written with a trained dictionary; supply the "
                "dictionary bytes it was written with")
        zd = zstandard.ZstdCompressionDict(dictionary)
        try:
            return zstandard.ZstdDecompressor(dict_data=zd).decompress(blob[4:])
        except zstandard.ZstdError as e:
            raise CompressionError(f"dictionary decompression failed "
                                   f"(wrong dictionary?): {e}") from None
    if tag == _ZSTD_FRAME_MAGIC:  # pre-tagging blob
        if not HAS_ZSTD:
            raise CompressionError(
                "legacy zstd blob requires the 'zstandard' module")
        return zstandard.ZstdDecompressor().decompress(blob)
    raise CompressionError(f"unrecognized blob header {tag!r}")


def codec_name() -> str:
    """The codec new blobs will be written with ('zstd' or 'zlib')."""
    return "zstd" if HAS_ZSTD else "zlib"
