"""Codec-tagged blob compression with graceful degradation.

The platform persists state (``state.py`` filekv databases) and training
checkpoints (``train/checkpoint.py``) as compressed msgpack blobs.  zstd is
the preferred codec, but it is a third-party dependency; on clean
environments the hard import used to break *all* of ``repro.core`` at
collection time.  This module makes ``zstandard`` optional:

* every blob is prefixed with a 4-byte codec tag (``b"DXZ1"`` = zstd,
  ``b"DXL1"`` = stdlib zlib) so readers dispatch on what was actually
  written, regardless of what is importable today;
* writers pick zstd when available, else zlib — both are self-describing;
* legacy untagged blobs (raw zstd frames, magic ``28 B5 2F FD``) written
  before tagging existed are still readable when zstd is installed.
"""
from __future__ import annotations

import zlib

try:
    import zstandard
    HAS_ZSTD = True
except ImportError:  # clean environment: fall back to stdlib
    zstandard = None  # type: ignore[assignment]
    HAS_ZSTD = False

TAG_ZSTD = b"DXZ1"
TAG_ZLIB = b"DXL1"
_ZSTD_FRAME_MAGIC = b"\x28\xb5\x2f\xfd"  # legacy untagged blobs


class CompressionError(RuntimeError):
    pass


def compress(data: bytes, *, level: int = 3) -> bytes:
    """Compress ``data`` with the best available codec; returns a tagged blob."""
    if HAS_ZSTD:
        return TAG_ZSTD + zstandard.ZstdCompressor(level=level).compress(data)
    return TAG_ZLIB + zlib.compress(data, level)


def decompress(blob: bytes) -> bytes:
    """Inverse of :func:`compress`; dispatches on the codec tag."""
    tag = blob[:4]
    if tag == TAG_ZLIB:
        return zlib.decompress(blob[4:])
    if tag == TAG_ZSTD:
        if not HAS_ZSTD:
            raise CompressionError(
                "blob was written with zstd but the 'zstandard' module is "
                "not installed; install it to read this data")
        return zstandard.ZstdDecompressor().decompress(blob[4:])
    if tag == _ZSTD_FRAME_MAGIC:  # pre-tagging blob
        if not HAS_ZSTD:
            raise CompressionError(
                "legacy zstd blob requires the 'zstandard' module")
        return zstandard.ZstdDecompressor().decompress(blob)
    raise CompressionError(f"unrecognized blob header {tag!r}")


def codec_name() -> str:
    """The codec new blobs will be written with ('zstd' or 'zlib')."""
    return "zstd" if HAS_ZSTD else "zlib"
