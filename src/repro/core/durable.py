"""Durable streams — append-only per-subject logs with replay and retention.

DataX subjects are fire-and-forget: a late subscriber sees nothing, a crash
loses in-flight history, and the paper's reuse story ("effortless reuse of
microservices and data streams") stops at whoever happened to be listening.
This module is the opt-in durability layer underneath the bus:

* :class:`DurableLog` — an append-only log of codec-tagged compressed blobs
  (``core/compression.py``), organized as **rolling segments**.  Every
  publish on a durable subject appends one record ``(offset, blob)`` where
  ``offset`` is a dense monotone sequence starting at 0; the offset rides on
  the delivered message as ``headers["offset"]``, which is what lets
  consumers pair state snapshots with log positions (exactly-once keyed
  recovery) and lets a replaying subscriber hand off to live delivery with
  no gaps and no duplicates.

* **Retention** (:class:`Retention`) — by record count, age, and/or total
  blob bytes.  Whole *sealed* segments are evicted at append time (the
  active segment never is), and evictions are counted so the metrics
  surface shows history being dropped.

* **Catalog** — per-log metadata (subject → segments, offset range, schema
  fingerprint, ``last_update``, trained dictionary) with optional on-disk
  persistence under a root directory: sealed segments are written as files
  and the catalog as ``catalog.dxc``, so a restarted process finds the
  history it wrote (H-STREAM's "query live streams and their histories"
  through one abstraction; the atd-data-lake catalog + ``last_update``
  incremental-reprocessing pattern).

* **Dictionary-trained compression** — the first ``train_dict_after``
  encoded messages of a subject train a zstd dictionary
  (:func:`~.compression.train_dictionary`); subsequent blobs compress with
  it (tag ``DXZ2``).  The dictionary is stored in the catalog/on disk so
  replay can decode, and the zlib leg degrades to plain tagged blobs.

The bus integration lives in ``bus.py`` (``MessageBus.make_durable``,
``subscribe(replay_from=...)``); the keyed exactly-once recovery helpers
pair a :class:`~.state.KeyedStore` snapshot watermark with a log offset
(:func:`resolve_replay_from`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import os
import threading
import time
from typing import TYPE_CHECKING, Iterable

import msgpack

from .compression import (HAS_ZSTD, TAG_ZLIB, TAG_ZSTD_DICT, compress,
                          decompress, train_dictionary)
from .schema import Message, StreamSchema

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (state -> bus)
    from .state import Database


class DurableError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# Retention policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Retention:
    """How much history a durable subject keeps (None = unbounded).

    Limits compose (evict until ALL are satisfied); eviction granularity is
    a whole sealed segment, so the live bound is approximate by up to one
    segment.  The active (still-filling) segment is never evicted.
    """

    max_records: int | None = None   # total retained records
    max_age_s: float | None = None   # drop segments whose newest record is older
    max_bytes: int | None = None     # total retained compressed bytes
    #                                  (sealed segments; the active segment
    #                                  counts once it seals)

    @staticmethod
    def of(spec: "Retention | dict | None") -> "Retention":
        """Coerce the plumbing-friendly forms (dict from a StreamSpec,
        None = keep everything) into a Retention."""
        if spec is None:
            return Retention()
        if isinstance(spec, Retention):
            return spec
        unknown = set(spec) - {"max_records", "max_age_s", "max_bytes"}
        if unknown:
            raise DurableError(f"unknown retention keys {sorted(unknown)}; "
                               f"allowed: max_records, max_age_s, max_bytes")
        return Retention(**spec)


def schema_fingerprint(schema: StreamSchema | None) -> str:
    """Stable digest of a stream schema — recorded in the catalog so an
    offline reader can detect that history predates a schema change."""
    if schema is None or not schema.fields:
        return "untyped"
    parts = [f"{name}:{f.kind}:{f.shape}:{f.dtype}"
             for name, f in sorted(schema.fields.items())]
    return hashlib.blake2s("|".join(parts).encode(),
                           digest_size=8).hexdigest()


# ---------------------------------------------------------------------------
# Record encoding — one record per published message
# ---------------------------------------------------------------------------

# A record blob is the wire encoding of the full message (subject, seq, ts,
# headers, payload — numpy-aware msgpack from bus.py), compressed into a
# codec-tagged blob.  Self-describing except for DXZ2 dictionary blobs,
# whose dictionary the log stores.

def _encode_record(msg: Message) -> bytes:
    from .bus import encode_message  # late import: bus imports this module
    return encode_message(msg)


def _decode_record(subject: str, offset: int, raw: bytes) -> Message:
    from .bus import decode_message
    msg = decode_message(raw)
    msg.headers["offset"] = offset
    return msg


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------

class Segment:
    """One contiguous run of records ``[base_offset, base_offset + n)``.

    While ACTIVE, records are the published :class:`Message` objects
    themselves — the append hot path neither encodes nor compresses (the
    same object the in-process bus hands its subscribers, so sharing it is
    no new aliasing).  Sealing bulk-encodes the run, packs it, and
    compresses it into ONE codec-tagged blob (tag ``DXZ2`` when the log's
    trained dictionary applies), which amortizes both the encoder and the
    codec to a single pass per ``segment_records`` appends and compresses
    far better than per-record blobs.  Per-record timestamps survive
    sealing (``tss``) so ``offset_at_ts`` never needs to decompress.
    """

    def __init__(self, base_offset: int):
        self.base_offset = base_offset
        # (ts, item) where item is a live Message (fresh appends) or raw
        # encoded bytes (a tail reloaded from disk); None once sealed
        self.records: list[tuple[float, object]] | None = []
        self.blob: bytes | None = None       # compressed run, once sealed
        self.tss: list[float] = []           # per-record ts, once sealed
        self.count = 0
        self.bytes = 0                       # compressed blob bytes (sealed)
        self.created_ts = time.time()
        self.last_ts = self.created_ts
        self.sealed = False

    def append(self, ts: float, item: "Message | bytes") -> None:
        self.records.append((ts, item))      # type: ignore[union-attr]
        self.count += 1
        self.last_ts = ts

    def _encoded_records(self) -> list[tuple[float, bytes]]:
        return [(ts, item if isinstance(item, (bytes, bytearray))
                 else _encode_record(item))   # type: ignore[arg-type]
                for ts, item in self.records]  # type: ignore[union-attr]

    def seal(self, level: int, dictionary: bytes | None) -> None:
        if self.blob is not None:
            self.sealed = True
            return
        packed = msgpack.packb(self._encoded_records(), use_bin_type=True)
        self.blob = compress(packed, level=level, dictionary=dictionary)
        self.tss = [ts for ts, _ in self.records]   # type: ignore[union-attr]
        self.bytes = len(self.blob)
        self.records = None
        self.sealed = True

    @property
    def next_offset(self) -> int:
        return self.base_offset + self.count

    def __len__(self) -> int:
        return self.count

    # -- (de)serialization (on-disk segment files) ---------------------------
    def to_bytes(self) -> bytes:
        if self.blob is not None:
            return msgpack.packb(
                {"base": self.base_offset, "created": self.created_ts,
                 "last": self.last_ts, "tss": self.tss, "blob": self.blob},
                use_bin_type=True)
        return msgpack.packb(
            {"base": self.base_offset, "created": self.created_ts,
             "records": self._encoded_records() if self.records else []},
            use_bin_type=True)

    @staticmethod
    def from_bytes(raw: bytes) -> "Segment":
        obj = msgpack.unpackb(raw, raw=False, strict_map_key=False)
        seg = Segment(obj["base"])
        seg.created_ts = obj["created"]
        if "blob" in obj:
            seg.blob = obj["blob"]
            seg.tss = list(obj["tss"])
            seg.count = len(seg.tss)
            seg.bytes = len(seg.blob)
            seg.last_ts = obj.get("last", seg.created_ts)
            seg.records = None
        else:
            for ts, rec in obj["records"]:
                seg.append(ts, rec)
        seg.sealed = True
        return seg


# ---------------------------------------------------------------------------
# The log
# ---------------------------------------------------------------------------

#: Records per segment before it seals and a new one starts.  Small enough
#: that retention (whole-segment granularity) tracks its limits closely,
#: large enough that the per-segment bookkeeping stays negligible.
DEFAULT_SEGMENT_RECORDS = 256

#: Encoded messages sampled before training the compression dictionary.
DEFAULT_TRAIN_AFTER = 64

_CATALOG_FILE = "catalog.dxc"
_DICT_FILE = "dict.bin"


class DurableLog:
    """Append-only log of one subject's messages, with rolling segments,
    retention, and an optional on-disk catalog.

    Thread-safe: ``append`` is called from every publisher of the subject,
    ``read`` from every replaying subscriber.  Offsets are dense (0, 1, 2,
    ...) and never reused; eviction moves ``earliest_offset`` forward.
    """

    def __init__(self, subject: str, *,
                 retention: Retention | dict | None = None,
                 root: str | None = None,
                 segment_records: int = DEFAULT_SEGMENT_RECORDS,
                 train_dict_after: int | None = DEFAULT_TRAIN_AFTER,
                 schema: StreamSchema | None = None,
                 compress_level: int = 1):
        self.subject = subject
        self.retention = Retention.of(retention)
        self.root = root
        self.segment_records = max(1, segment_records)
        self.fingerprint = schema_fingerprint(schema)
        self._level = compress_level
        self._lock = threading.Lock()
        self._segments: list[Segment] = [Segment(0)]
        self._cache_base = -1               # one-entry sealed-segment cache
        self._cache_records: list = []
        self.evicted_records = 0
        self.evicted_segments = 0
        self.last_update = 0.0
        # dictionary training state
        self._train_after = train_dict_after if train_dict_after else 0
        self._train_samples: list[bytes] = []
        self._dict: bytes | None = None
        if root:
            os.makedirs(root, exist_ok=True)
            self._load_locked()

    # -- append path ---------------------------------------------------------
    def append(self, msg: Message) -> int:
        """Append one message; returns its offset (dense, monotone).

        The hot path is a lock + list-append — encoding AND compression
        happen once per segment at roll time (:meth:`Segment.seal`), so a
        durable publish stays within the CI-gated overhead bound.  (While
        the dictionary trainer still needs samples, the first
        ``train_dict_after`` appends do encode — a one-time cost.)"""
        with self._lock:
            if self._dict is None and self._train_after:
                self._train_samples.append(_encode_record(msg))
                if len(self._train_samples) >= self._train_after:
                    self._dict = train_dictionary(self._train_samples)
                    self._train_samples = []
                    self._train_after = 0   # one-shot: train once per subject
                    if self._dict is not None and self.root:
                        self._write_file(_DICT_FILE, self._dict)
            seg = self._segments[-1]
            if seg.sealed or len(seg) >= self.segment_records:
                seg = self._roll_locked()
            offset = seg.next_offset
            now = time.time()
            seg.append(now, msg)
            self.last_update = now
            self._enforce_retention_locked()
            return offset

    def _roll_locked(self) -> Segment:
        old = self._segments[-1]
        old.seal(self._level, self._dict)
        if self.root:
            self._write_file(f"seg-{old.base_offset:012d}.dxl", old.to_bytes())
            self._write_catalog_locked()
        seg = Segment(old.next_offset)
        self._segments.append(seg)
        return seg

    def _enforce_retention_locked(self) -> None:
        r = self.retention
        if r.max_records is None and r.max_age_s is None \
                and r.max_bytes is None:
            return
        now = time.time()
        while len(self._segments) > 1:   # the active segment never evicts
            head = self._segments[0]
            total_records = sum(len(s) for s in self._segments)
            total_bytes = sum(s.bytes for s in self._segments)
            over = (
                (r.max_records is not None and total_records > r.max_records)
                or (r.max_bytes is not None and total_bytes > r.max_bytes)
                or (r.max_age_s is not None
                    and now - head.last_ts > r.max_age_s))
            if not over:
                break
            self._segments.pop(0)
            self.evicted_records += len(head)
            self.evicted_segments += 1
            if self._cache_base == head.base_offset:
                self._cache_base, self._cache_records = -1, []
            if self.root:
                path = os.path.join(self.root,
                                    f"seg-{head.base_offset:012d}.dxl")
                if os.path.exists(path):
                    os.remove(path)

    # -- read path -----------------------------------------------------------
    def next_offset(self) -> int:
        """The offset the NEXT append will get (== current log head)."""
        with self._lock:
            return self._segments[-1].next_offset

    def earliest_offset(self) -> int:
        """Oldest retained offset (== next_offset when the log is empty)."""
        with self._lock:
            return self._segments[0].base_offset

    def offset_at_ts(self, ts: float) -> int:
        """First retained offset whose record ts >= ``ts`` (log head if the
        whole retained history predates ``ts``).  Served from the per-record
        timestamps — sealed segments are never decompressed for this."""
        with self._lock:
            for seg in self._segments:
                if seg.last_ts < ts and len(seg):
                    continue
                tss = seg.tss if seg.records is None \
                    else [rts for rts, _ in seg.records]
                for i, rts in enumerate(tss):
                    if rts >= ts:
                        return seg.base_offset + i
            return self._segments[-1].next_offset

    def read(self, from_offset: int, max_n: int = 64) -> list[Message]:
        """Up to ``max_n`` decoded messages starting at ``from_offset``
        (clamped to the earliest retained offset).  Empty list = caught up.

        Each returned message carries its log position in
        ``headers["offset"]`` — identical to live delivery on a durable
        subject, so consumers never branch on replay-vs-live.
        """
        with self._lock:
            cursor = max(from_offset, self._segments[0].base_offset)
            plan: list[tuple[Segment, list | None]] = []
            served = 0
            for seg in self._segments:
                if seg.next_offset <= cursor or not len(seg):
                    continue
                # active segment: snapshot under the lock (it still grows);
                # sealed segments are immutable and decompress outside it
                plan.append((seg, list(seg.records)
                             if seg.records is not None else None))
                served += seg.next_offset - max(cursor, seg.base_offset)
                if served >= max_n:
                    break
            dictionary = self._dict
        msgs: list[Message] = []
        for seg, records in plan:
            if records is None:
                records = self._sealed_records(seg, dictionary)
            start = max(0, cursor - seg.base_offset)
            for i in range(start, len(records)):
                if len(msgs) >= max_n:
                    return msgs
                off = seg.base_offset + i
                item = records[i][1]
                if isinstance(item, (bytes, bytearray)):
                    msgs.append(_decode_record(self.subject, off, item))
                else:
                    # active-segment record: still a live Message — return a
                    # fresh envelope (same payload object, like in-proc
                    # delivery) with its log position stamped
                    msgs.append(Message(
                        subject=item.subject, payload=item.payload,
                        seq=item.seq, ts=item.ts,
                        headers={**item.headers, "offset": off}))
            cursor = seg.base_offset + len(records)
        return msgs

    def _sealed_records(self, seg: Segment,
                        dictionary: bytes | None) -> list:
        """Decompress a sealed segment's record run, with a one-entry cache
        — replay reads are sequential, so consecutive calls hit the same
        segment and pay the codec once."""
        with self._lock:
            if self._cache_base == seg.base_offset:
                return self._cache_records
        packed = decompress(seg.blob, dictionary=dictionary)  # type: ignore[arg-type]
        records = msgpack.unpackb(packed, raw=False)
        with self._lock:
            self._cache_base, self._cache_records = seg.base_offset, records
        return records

    # -- catalog -------------------------------------------------------------
    def info(self) -> dict:
        """The catalog entry: depth, segment/offset ranges, retention
        evictions, schema fingerprint, last_update — the sidecar surfaces
        this through its REST metrics and offline readers use it to bound
        incremental re-runs (the atd-data-lake ``last_update`` pattern)."""
        with self._lock:
            return {
                "subject": self.subject,
                "depth": sum(len(s) for s in self._segments),
                "bytes": sum(s.bytes for s in self._segments),
                "segments": len(self._segments),
                "earliest_offset": self._segments[0].base_offset,
                "next_offset": self._segments[-1].next_offset,
                "evicted_records": self.evicted_records,
                "evicted_segments": self.evicted_segments,
                "schema_fingerprint": self.fingerprint,
                "dict_trained": self._dict is not None,
                "last_update": self.last_update,
            }

    # -- persistence ---------------------------------------------------------
    def _write_file(self, name: str, data: bytes) -> None:
        path = os.path.join(self.root, name)           # type: ignore[arg-type]
        tmp = f"{path}.tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)

    def _write_catalog_locked(self) -> None:
        cat = {
            "subject": self.subject,
            "fingerprint": self.fingerprint,
            "segments": [s.base_offset for s in self._segments if s.sealed],
            "next_offset": self._segments[-1].next_offset,
            "evicted_records": self.evicted_records,
            "evicted_segments": self.evicted_segments,
            "last_update": self.last_update,
            "has_dict": self._dict is not None,
        }
        self._write_file(_CATALOG_FILE, compress(
            msgpack.packb(cat, use_bin_type=True), level=self._level))

    def flush(self) -> None:
        """Persist the active segment + catalog (root-backed logs only).

        Sealed segments are written as they roll; this makes the tail
        durable too (called at close/teardown and by tests)."""
        if not self.root:
            return
        with self._lock:
            seg = self._segments[-1]
            self._write_file(f"seg-{seg.base_offset:012d}.dxl", seg.to_bytes())
            self._write_catalog_locked()

    def _blob_readable(self, blob: bytes | None) -> bool:
        """Can a sealed blob be decompressed in THIS environment?  Raw-record
        segments (no blob) always can; ``DXL1`` is stdlib; ``DXZ1``/legacy
        frames need zstd; ``DXZ2`` needs the (already validated) dictionary."""
        if blob is None:
            return True
        tag = bytes(blob[:4])
        if tag == TAG_ZLIB:
            return True
        if tag == TAG_ZSTD_DICT:
            return HAS_ZSTD and self._dict is not None
        return HAS_ZSTD   # DXZ1 or a legacy untagged zstd frame

    def _load_locked(self) -> None:
        cat_path = os.path.join(self.root, _CATALOG_FILE)  # type: ignore[arg-type]
        if not os.path.exists(cat_path):
            return
        with open(cat_path, "rb") as f:
            cat = msgpack.unpackb(decompress(f.read()), raw=False,
                                  strict_map_key=False)
        if cat.get("has_dict"):
            # A missing dict.bin must not fail the catalog load: DXZ2
            # segments become unreadable (dropped below, counted as
            # evictions) but self-describing history still loads.
            dict_path = os.path.join(self.root, _DICT_FILE)  # type: ignore[arg-type]
            if os.path.exists(dict_path):
                with open(dict_path, "rb") as f:
                    self._dict = f.read()
        segments: list[Segment] = []
        for name in sorted(os.listdir(self.root)):       # type: ignore[arg-type]
            if not (name.startswith("seg-") and name.endswith(".dxl")):
                continue
            with open(os.path.join(self.root, name), "rb") as f:  # type: ignore[arg-type]
                segments.append(Segment.from_bytes(f.read()))
        if self._dict is not None:
            # A present-but-corrupt dict.bin must degrade exactly like a
            # missing one — validate against the first dictionary-tagged
            # blob before trusting it for every later read
            probe = next((s.blob for s in segments if s.blob is not None
                          and bytes(s.blob[:4]) == TAG_ZSTD_DICT), None)
            try:
                if probe is not None:
                    decompress(probe, dictionary=self._dict)
            except Exception:   # zstd raises its own types on garbage dicts
                self._dict = None
        if self._dict is not None:
            self._train_after = 0   # keep using the persisted dictionary
        kept: list[Segment] = []
        dropped_records = dropped_segments = 0
        for seg in segments:
            if self._blob_readable(seg.blob):
                kept.append(seg)
                continue
            dropped_records += len(seg)
            dropped_segments += 1
            path = os.path.join(self.root,               # type: ignore[arg-type]
                                f"seg-{seg.base_offset:012d}.dxl")
            if os.path.exists(path):
                os.remove(path)
        if kept and kept[-1] is segments[-1]:
            self._segments = kept
            tail = self._segments[-1]
            if tail.records is None:
                # the tail rolled (blob form) before the process died —
                # reopen it for appends by unpacking the run back to raw
                packed = decompress(tail.blob,  # type: ignore[arg-type]
                                    dictionary=self._dict)
                tail.records = [(ts, rec) for ts, rec in
                                msgpack.unpackb(packed, raw=False)]
                tail.bytes = sum(len(rec) for _, rec in tail.records)
                tail.blob = None
                tail.tss = []
            tail.sealed = False   # resume appending to the tail
        elif segments:
            # the on-disk tail was unreadable (or nothing survived): resume
            # appending at the old head so offsets stay dense and monotone
            head = max(cat.get("next_offset", 0), segments[-1].next_offset)
            self._segments = kept + [Segment(head)]
        self.evicted_records = cat.get("evicted_records", 0) + dropped_records
        self.evicted_segments = (cat.get("evicted_segments", 0)
                                 + dropped_segments)
        self.last_update = cat.get("last_update", 0.0)

    def close(self) -> None:
        self.flush()

    def drop(self) -> None:
        """Delete on-disk state (subject unregistered)."""
        if not self.root or not os.path.isdir(self.root):
            return
        for name in os.listdir(self.root):
            if name == _CATALOG_FILE or name == _DICT_FILE \
                    or (name.startswith("seg-") and name.endswith(".dxl")):
                try:
                    os.remove(os.path.join(self.root, name))
                except OSError:  # pragma: no cover - best-effort cleanup
                    pass


# ---------------------------------------------------------------------------
# Exactly-once keyed recovery — snapshot watermark resolution
# ---------------------------------------------------------------------------

#: Table (in a stream's platform database) where KeyedStore.snapshot()
#: records per-owner watermarks: all log offsets <= watermark are applied.
SNAPSHOT_TABLE = "__snapshots__"


def resolve_replay_from(replay_from, db: "Database | None"):
    """Resolve a StreamSpec's ``replay_from`` into a bus-level position.

    ``"snapshot"`` reads the stream database's snapshot watermarks
    (:data:`SNAPSHOT_TABLE`, written by ``KeyedStore.snapshot``) and replays
    from the SUFFIX after the oldest one — the exactly-once recovery
    contract: state up to the watermark is already in the store, so only
    later offsets need reprocessing (per-key applied-offset dedupe makes an
    over-long replay safe, never incorrect).  No snapshot yet → replay from
    ``"earliest"``.  Every other value passes through unchanged (offset int,
    timestamp float, ``"earliest"``).
    """
    if replay_from != "snapshot":
        return replay_from
    if db is None:
        return "earliest"
    try:
        table = db.table(SNAPSHOT_TABLE)
    except Exception:
        return "earliest"
    marks = [row.get("watermark") for _, row in table.scan()
             if row.get("watermark") is not None]
    if not marks:
        return "earliest"
    return int(min(marks)) + 1


def iter_log(log: DurableLog, from_offset: int = 0,
             batch: int = 64) -> Iterable[Message]:
    """Convenience iterator over the retained history (offline/queries)."""
    cursor = max(from_offset, log.earliest_offset())
    while True:
        msgs = log.read(cursor, batch)
        if not msgs:
            return
        yield from msgs
        cursor = msgs[-1].headers["offset"] + 1


__all__ = [
    "DurableError", "DurableLog", "Retention", "Segment", "SNAPSHOT_TABLE",
    "iter_log", "resolve_replay_from", "schema_fingerprint",
]
